//! Coordinate-format sparse matrix (construction format).

use crate::error::{Error, Result};

/// COO triplet matrix. The natural construction format; convert to
/// [`crate::sparse::Csr`] for compute.
#[derive(Debug, Clone, PartialEq)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub entries: Vec<(usize, usize, f64)>,
}

impl Coo {
    pub fn new(rows: usize, cols: usize) -> Self {
        Coo { rows, cols, entries: Vec::new() }
    }

    pub fn with_capacity(rows: usize, cols: usize, cap: usize) -> Self {
        Coo { rows, cols, entries: Vec::with_capacity(cap) }
    }

    /// Append an entry (no dedup here; see [`Coo::sum_duplicates`]).
    #[inline]
    pub fn push(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols, "entry ({i},{j}) out of {}x{}", self.rows, self.cols);
        self.entries.push((i, j, v));
    }

    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Sparsity per the paper: sp(A) = 1 − |A| / (m·n).
    pub fn sparsity(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 1.0;
        }
        1.0 - self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Sort by (row, col) and sum duplicate coordinates.
    pub fn sum_duplicates(&mut self) {
        self.entries.sort_unstable_by_key(|&(i, j, _)| (i, j));
        let mut out: Vec<(usize, usize, f64)> = Vec::with_capacity(self.entries.len());
        for &(i, j, v) in &self.entries {
            match out.last_mut() {
                Some(last) if last.0 == i && last.1 == j => last.2 += v,
                _ => out.push((i, j, v)),
            }
        }
        self.entries = out;
    }

    /// Validate all coordinates are in range.
    pub fn validate(&self) -> Result<()> {
        for &(i, j, _) in &self.entries {
            if i >= self.rows || j >= self.cols {
                return Err(Error::Invalid(format!(
                    "coo entry ({i},{j}) out of bounds {}x{}",
                    self.rows, self.cols
                )));
            }
        }
        Ok(())
    }

    /// Dense copy (test/small use only).
    pub fn to_dense(&self) -> crate::dense::Matrix {
        let mut m = crate::dense::Matrix::zeros(self.rows, self.cols);
        for &(i, j, v) in &self.entries {
            m[(i, j)] += v;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_stats() {
        let mut c = Coo::new(3, 4);
        c.push(0, 0, 1.0);
        c.push(2, 3, 2.0);
        assert_eq!(c.nnz(), 2);
        assert!((c.sparsity() - (1.0 - 2.0 / 12.0)).abs() < 1e-12);
    }

    #[test]
    fn sum_duplicates_merges() {
        let mut c = Coo::new(2, 2);
        c.push(1, 1, 1.0);
        c.push(0, 0, 2.0);
        c.push(1, 1, 3.0);
        c.sum_duplicates();
        assert_eq!(c.nnz(), 2);
        assert_eq!(c.entries, vec![(0, 0, 2.0), (1, 1, 4.0)]);
    }

    #[test]
    fn validate_catches_out_of_range() {
        let c = Coo { rows: 2, cols: 2, entries: vec![(5, 0, 1.0)] };
        assert!(c.validate().is_err());
    }
}
