//! Row-wise train/test split of (feature, label) matrix pairs.

use crate::sparse::{Coo, Csr};
use crate::util::rng::Rng;

/// A train/test split of a multi-label dataset.
#[derive(Debug, Clone)]
pub struct Split {
    pub a_train: Csr,
    pub y_train: Csr,
    pub a_test: Csr,
    pub y_test: Csr,
    /// original row ids of the test rows
    pub test_rows: Vec<usize>,
}

/// Split rows into train/test with `test_fraction` held out (paper: 10%).
pub fn train_test_split(a: &Csr, y: &Csr, test_fraction: f64, rng: &mut Rng) -> Split {
    assert_eq!(a.rows(), y.rows(), "feature/label row mismatch");
    assert!((0.0..1.0).contains(&test_fraction));
    let m = a.rows();
    let mut order = rng.permutation(m);
    let n_test = ((m as f64) * test_fraction).round() as usize;
    let mut test_rows: Vec<usize> = order.drain(..n_test).collect();
    // ascending so test_rows[i] is the original id of a_test row i
    test_rows.sort_unstable();
    let mut is_test = vec![false; m];
    for &i in &test_rows {
        is_test[i] = true;
    }

    let take = |mat: &Csr, test: bool| -> Csr {
        let keep: Vec<usize> = (0..m).filter(|&i| is_test[i] == test).collect();
        let mut coo = Coo::new(keep.len(), mat.cols());
        for (new_i, &old_i) in keep.iter().enumerate() {
            let (js, vs) = mat.row(old_i);
            for (&j, &v) in js.iter().zip(vs) {
                coo.push(new_i, j, v);
            }
        }
        Csr::from_coo(&coo)
    };

    Split {
        a_train: take(a, false),
        y_train: take(y, false),
        a_test: take(a, true),
        y_test: take(y, true),
        test_rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;

    fn random_pair(rng: &mut Rng, m: usize, n: usize, l: usize) -> (Csr, Csr) {
        let mut a = Coo::new(m, n);
        let mut y = Coo::new(m, l);
        for i in 0..m {
            a.push(i, rng.usize_below(n), 1.0);
            y.push(i, rng.usize_below(l), 1.0);
        }
        (Csr::from_coo(&a), Csr::from_coo(&y))
    }

    #[test]
    fn split_sizes_and_alignment() {
        check("split sizes", 10, |rng| {
            let m = rng.usize_range(10, 100);
            let (a, y) = random_pair(rng, m, 8, 5);
            let s = train_test_split(&a, &y, 0.1, rng);
            let n_test = ((m as f64) * 0.1).round() as usize;
            assert_eq!(s.a_test.rows(), n_test);
            assert_eq!(s.y_test.rows(), n_test);
            assert_eq!(s.a_train.rows(), m - n_test);
            assert_eq!(s.a_train.rows(), s.y_train.rows());
            // nnz conserved
            assert_eq!(s.a_train.nnz() + s.a_test.nnz(), a.nnz());
            assert_eq!(s.y_train.nnz() + s.y_test.nnz(), y.nnz());
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let (a, y) = random_pair(&mut Rng::seed_from_u64(1), 50, 8, 5);
        let s1 = train_test_split(&a, &y, 0.2, &mut Rng::seed_from_u64(9));
        let s2 = train_test_split(&a, &y, 0.2, &mut Rng::seed_from_u64(9));
        assert_eq!(s1.test_rows, s2.test_rows);
        assert_eq!(s1.a_train, s2.a_train);
    }

    #[test]
    fn rows_preserved_exactly() {
        let (a, y) = random_pair(&mut Rng::seed_from_u64(2), 30, 6, 4);
        let s = train_test_split(&a, &y, 0.3, &mut Rng::seed_from_u64(3));
        let ad = a.to_dense();
        for (new_i, &old_i) in s.test_rows.iter().enumerate() {
            let (js, vs) = s.a_test.row(new_i);
            for (&j, &v) in js.iter().zip(vs) {
                assert_eq!(ad[(old_i, j)], v);
            }
        }
    }
}
