//! Ranking metrics for multi-label prediction: P@k (the paper's Figure-5
//! metric) and nDCG@k.

use crate::dense::Matrix;
use crate::sparse::Csr;

/// Indices of the k largest entries of `scores`, descending (ties by index).
///
/// Ranking uses `f64::total_cmp`, so a NaN score (a degenerate model can
/// produce one even though the serving path rejects non-finite *inputs*)
/// ranks deterministically instead of panicking the whole metric/batch:
/// IEEE total order puts positive NaN above +∞ and negative NaN below −∞.
pub fn top_k_indices(scores: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    let k = k.min(scores.len());
    idx.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]).then(a.cmp(&b)));
    idx.truncate(k);
    idx
}

/// Mean precision@k: P@k = (1/k) Σ_{l ∈ rank_k(ŷ)} y_l averaged over rows.
/// `scores` is (instances × labels) dense; `y_true` is the binary sparse
/// ground truth of the same shape.
pub fn precision_at_k(scores: &Matrix, y_true: &Csr, k: usize) -> f64 {
    assert_eq!(scores.shape(), y_true.shape(), "score/label shape mismatch");
    assert!(k > 0);
    let m = scores.rows();
    if m == 0 {
        return 0.0;
    }
    let mut total = 0.0;
    for i in 0..m {
        let (js, _) = y_true.row(i);
        let top = top_k_indices(scores.row(i), k);
        let hits = top.iter().filter(|t| js.contains(t)).count();
        total += hits as f64 / k as f64;
    }
    total / m as f64
}

/// Mean nDCG@k with binary relevance.
pub fn ndcg_at_k(scores: &Matrix, y_true: &Csr, k: usize) -> f64 {
    assert_eq!(scores.shape(), y_true.shape());
    assert!(k > 0);
    let m = scores.rows();
    if m == 0 {
        return 0.0;
    }
    let mut total = 0.0;
    let mut counted = 0usize;
    for i in 0..m {
        let (js, _) = y_true.row(i);
        if js.is_empty() {
            continue; // nDCG undefined with no relevant labels
        }
        let top = top_k_indices(scores.row(i), k);
        let dcg: f64 = top
            .iter()
            .enumerate()
            .filter(|(_, t)| js.contains(t))
            .map(|(rank, _)| 1.0 / ((rank as f64 + 2.0).log2()))
            .sum();
        let ideal: f64 =
            (0..js.len().min(k)).map(|rank| 1.0 / ((rank as f64 + 2.0).log2())).sum();
        total += dcg / ideal;
        counted += 1;
    }
    if counted == 0 {
        0.0
    } else {
        total / counted as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    fn labels(rows: &[&[usize]], l: usize) -> Csr {
        let mut coo = Coo::new(rows.len(), l);
        for (i, r) in rows.iter().enumerate() {
            for &j in *r {
                coo.push(i, j, 1.0);
            }
        }
        Csr::from_coo(&coo)
    }

    #[test]
    fn top_k_basic() {
        assert_eq!(top_k_indices(&[0.1, 0.9, 0.5], 2), vec![1, 2]);
        assert_eq!(top_k_indices(&[1.0, 1.0], 1), vec![0]); // tie → lower index
        assert_eq!(top_k_indices(&[0.3], 5), vec![0]);
    }

    #[test]
    fn nan_scores_rank_deterministically_instead_of_panicking() {
        // regression: partial_cmp().unwrap() panicked metric computation on
        // a single NaN score. total_cmp ranks it: +NaN above everything,
        // -NaN below everything, everything else unchanged.
        let scores = [0.5, f64::NAN, 0.9, -f64::NAN];
        let top = top_k_indices(&scores, 4);
        assert_eq!(top, vec![1, 2, 0, 3]);
        assert_eq!(top_k_indices(&scores, 2), vec![1, 2]);

        // ...and the row-level metrics stay total on NaN-bearing scores
        let m = Matrix::from_rows(&[&[f64::NAN, 0.5, 0.1], &[0.2, 0.8, -f64::NAN]]);
        let y = labels(&[&[1], &[1]], 3);
        let p = precision_at_k(&m, &y, 1);
        assert!((0.0..=1.0).contains(&p), "P@1 must stay bounded: {p}");
        // row 0: +NaN outranks the true label → miss; row 1: −NaN sinks to
        // the bottom and label 1 wins → hit
        assert!((p - 0.5).abs() < 1e-12, "{p}");
        let nd = ndcg_at_k(&m, &y, 2);
        assert!(nd.is_finite() && (0.0..=1.0 + 1e-12).contains(&nd), "{nd}");
    }

    #[test]
    fn perfect_and_zero_precision() {
        let scores = Matrix::from_rows(&[&[0.9, 0.8, 0.1, 0.0]]);
        let y_hit = labels(&[&[0, 1]], 4);
        assert_eq!(precision_at_k(&scores, &y_hit, 2), 1.0);
        let y_miss = labels(&[&[2, 3]], 4);
        assert_eq!(precision_at_k(&scores, &y_miss, 2), 0.0);
    }

    #[test]
    fn partial_precision_averaged() {
        let scores = Matrix::from_rows(&[&[0.9, 0.8, 0.1], &[0.1, 0.2, 0.9]]);
        // row 0: top2 = {0,1}, true = {0} -> 0.5; row 1: top2 = {2,1}, true = {1,2} -> 1.0
        let y = labels(&[&[0], &[1, 2]], 3);
        let p = precision_at_k(&scores, &y, 2);
        assert!((p - 0.75).abs() < 1e-12);
    }

    #[test]
    fn p_at_k_in_unit_interval() {
        use crate::util::propcheck::check;
        check("P@k bounded", 15, |rng| {
            let (m, l) = (rng.usize_range(1, 20), rng.usize_range(2, 15));
            let scores = Matrix::randn(m, l, rng);
            let mut coo = Coo::new(m, l);
            for i in 0..m {
                if rng.f64() < 0.8 {
                    coo.push(i, rng.usize_below(l), 1.0);
                }
            }
            let y = Csr::from_coo(&coo);
            for k in 1..=3 {
                let p = precision_at_k(&scores, &y, k);
                assert!((0.0..=1.0).contains(&p));
                let nd = ndcg_at_k(&scores, &y, k);
                assert!((0.0..=1.0 + 1e-12).contains(&nd));
            }
        });
    }

    #[test]
    fn ndcg_rank_sensitivity() {
        // correct label at position 1 beats position 2
        let s1 = Matrix::from_rows(&[&[0.9, 0.5, 0.1]]);
        let s2 = Matrix::from_rows(&[&[0.5, 0.9, 0.1]]);
        let y = labels(&[&[0]], 3);
        assert!(ndcg_at_k(&s1, &y, 3) > ndcg_at_k(&s2, &y, 3));
    }
}
