//! Multi-label linear regression — the paper's Application 1 and the
//! accuracy experiment (Figure 5).

pub mod metrics;
pub mod mllr;
pub mod split;

pub use metrics::{ndcg_at_k, precision_at_k};
pub use mllr::{MultiLabelModel, TrainReport};
pub use split::{train_test_split, Split};
