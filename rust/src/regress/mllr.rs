//! Multi-label linear regression model: Z = A†·Y (Application 1).

use crate::dense::Matrix;
use crate::pinv::Pinv;
use crate::sparse::Csr;

/// Trained multi-label linear model: scores for a feature vector `a` are
/// `ŷ = Zᵀ·a`.
#[derive(Debug, Clone)]
pub struct MultiLabelModel {
    /// parameter matrix Z (n×L)
    pub z: Matrix,
}

/// Training summary.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub n_features: usize,
    pub n_labels: usize,
    pub rank: usize,
    pub train_secs: f64,
}

impl MultiLabelModel {
    /// Closed-form training: Z = A†·Y via the factored pseudoinverse.
    pub fn train(pinv: &Pinv, y_train: &Csr) -> (MultiLabelModel, TrainReport) {
        let t = std::time::Instant::now();
        let z = pinv.apply_sparse(y_train);
        let report = TrainReport {
            n_features: z.rows(),
            n_labels: z.cols(),
            rank: pinv.rank(),
            train_secs: t.elapsed().as_secs_f64(),
        };
        (MultiLabelModel { z }, report)
    }

    /// Score a batch of instances: S = A_test · Z (rows = instances).
    pub fn predict(&self, a_test: &Csr) -> Matrix {
        assert_eq!(a_test.cols(), self.z.rows(), "feature dim mismatch");
        a_test.spmm(&self.z)
    }

    /// Score a single sparse feature vector given as (indices, values).
    pub fn predict_one(&self, indices: &[usize], values: &[f64]) -> Vec<f64> {
        let l = self.z.cols();
        let mut out = vec![0.0; l];
        for (&j, &v) in indices.iter().zip(values) {
            assert!(j < self.z.rows(), "feature index {j} out of range");
            let zrow = self.z.row(j);
            for c in 0..l {
                out[c] += v * zrow[c];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dense::svd;
    use crate::sparse::Coo;
    use crate::util::rng::Rng;

    /// Exactly solvable system: Y = A·Z0 with A full column rank ⇒
    /// training recovers Z0 and predictions are exact.
    #[test]
    fn recovers_exact_linear_labels() {
        let mut rng = Rng::seed_from_u64(1);
        let a_dense = Matrix::randn(30, 8, &mut rng);
        let z0 = Matrix::randn(8, 5, &mut rng);
        let y_dense = crate::dense::matmul(&a_dense, &z0);

        let mut acoo = Coo::new(30, 8);
        for i in 0..30 {
            for j in 0..8 {
                acoo.push(i, j, a_dense[(i, j)]);
            }
        }
        let a = Csr::from_coo(&acoo);
        let mut ycoo = Coo::new(30, 5);
        for i in 0..30 {
            for j in 0..5 {
                if y_dense[(i, j)].abs() > 1e-12 {
                    ycoo.push(i, j, y_dense[(i, j)]);
                }
            }
        }
        let y = Csr::from_coo(&ycoo);

        let p = Pinv::from_svd(&svd(&a_dense));
        let (model, report) = MultiLabelModel::train(&p, &y);
        assert_eq!(report.n_features, 8);
        assert_eq!(report.n_labels, 5);
        assert!(model.z.max_abs_diff(&z0) < 1e-8, "Z recovery");

        let scores = model.predict(&a);
        assert!(scores.max_abs_diff(&y_dense) < 1e-7, "prediction");
    }

    #[test]
    fn predict_one_matches_batch() {
        let mut rng = Rng::seed_from_u64(2);
        let z = Matrix::randn(6, 4, &mut rng);
        let model = MultiLabelModel { z };
        let mut coo = Coo::new(3, 6);
        coo.push(0, 1, 2.0);
        coo.push(0, 4, -1.0);
        coo.push(2, 0, 3.0);
        let a = Csr::from_coo(&coo);
        let batch = model.predict(&a);
        let (js, vs) = a.row(0);
        let one = model.predict_one(js, vs);
        for c in 0..4 {
            assert!((one[c] - batch[(0, c)]).abs() < 1e-12);
        }
        // empty row scores zero
        let empty = model.predict_one(&[], &[]);
        assert!(empty.iter().all(|&x| x == 0.0));
        for c in 0..4 {
            assert_eq!(batch[(1, c)], 0.0);
        }
    }
}
