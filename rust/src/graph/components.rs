//! Connected components of the (live part of the) bipartite graph via BFS —
//! line 4 of Algorithm 2.

use super::bipartite::Bipartite;

/// Connected components over live nodes. Components are indexed 0..count;
/// each lists its instance rows and feature cols.
#[derive(Debug, Clone)]
pub struct Components {
    /// per component: (instance ids, feature ids)
    pub comps: Vec<(Vec<usize>, Vec<usize>)>,
    /// index into `comps` of the giant component (by total node count);
    /// None when there are no live nodes.
    pub giant: Option<usize>,
}

impl Components {
    /// Total number of components.
    pub fn count(&self) -> usize {
        self.comps.len()
    }

    /// Components other than the giant one, in discovery order.
    pub fn non_giant(&self) -> impl Iterator<Item = (usize, &(Vec<usize>, Vec<usize>))> {
        let giant = self.giant;
        self.comps
            .iter()
            .enumerate()
            .filter(move |(i, _)| Some(*i) != giant)
    }
}

/// BFS over live nodes of `g`, treating instance and feature nodes as one
/// vertex set. O(|V| + |E|).
pub fn connected_components(g: &Bipartite) -> Components {
    let m = g.num_instances();
    let n = g.num_features();
    let mut inst_comp = vec![usize::MAX; m];
    let mut feat_comp = vec![usize::MAX; n];
    let mut comps: Vec<(Vec<usize>, Vec<usize>)> = Vec::new();
    let mut queue: std::collections::VecDeque<(bool, usize)> = Default::default();

    // Seed BFS from every unvisited live node (instances, then features so
    // isolated features also form components).
    for start in 0..m + n {
        let (is_inst, id) = if start < m { (true, start) } else { (false, start - m) };
        let alive = if is_inst {
            g.is_alive(super::NodeId::Instance(id))
        } else {
            g.is_alive(super::NodeId::Feature(id))
        };
        if !alive {
            continue;
        }
        let seen = if is_inst { inst_comp[id] != usize::MAX } else { feat_comp[id] != usize::MAX };
        if seen {
            continue;
        }
        let c = comps.len();
        comps.push((Vec::new(), Vec::new()));
        queue.push_back((is_inst, id));
        if is_inst {
            inst_comp[id] = c;
        } else {
            feat_comp[id] = c;
        }
        while let Some((inst, v)) = queue.pop_front() {
            if inst {
                comps[c].0.push(v);
                for j in g.instance_neighbors(v) {
                    if feat_comp[j] == usize::MAX {
                        feat_comp[j] = c;
                        queue.push_back((false, j));
                    }
                }
            } else {
                comps[c].1.push(v);
                for i in g.feature_neighbors(v) {
                    if inst_comp[i] == usize::MAX {
                        inst_comp[i] = c;
                        queue.push_back((true, i));
                    }
                }
            }
        }
    }

    let giant = comps
        .iter()
        .enumerate()
        .max_by_key(|(_, (is, fs))| is.len() + fs.len())
        .map(|(i, _)| i);
    Components { comps, giant }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Csr};

    fn graph_from_edges(m: usize, n: usize, edges: &[(usize, usize)]) -> Bipartite {
        let mut coo = Coo::new(m, n);
        for &(i, j) in edges {
            coo.push(i, j, 1.0);
        }
        Bipartite::from_csr(&Csr::from_coo(&coo))
    }

    #[test]
    fn single_component() {
        let g = graph_from_edges(3, 2, &[(0, 0), (1, 0), (1, 1), (2, 1)]);
        let c = connected_components(&g);
        assert_eq!(c.count(), 1);
        assert_eq!(c.giant, Some(0));
        assert_eq!(c.comps[0].0.len(), 3);
        assert_eq!(c.comps[0].1.len(), 2);
    }

    #[test]
    fn two_components_and_isolated() {
        // comp A: rows {0,1} + col {0}; comp B: row {2} + col {1};
        // isolated: row 3 (degree 0), col 2 (degree 0)
        let g = graph_from_edges(4, 3, &[(0, 0), (1, 0), (2, 1)]);
        let c = connected_components(&g);
        assert_eq!(c.count(), 4);
        let giant = c.giant.unwrap();
        assert_eq!(c.comps[giant].0.len() + c.comps[giant].1.len(), 3);
        // all nodes covered exactly once
        let insts: usize = c.comps.iter().map(|(i, _)| i.len()).sum();
        let feats: usize = c.comps.iter().map(|(_, f)| f.len()).sum();
        assert_eq!(insts, 4);
        assert_eq!(feats, 3);
    }

    #[test]
    fn respects_removed_nodes() {
        let mut g = graph_from_edges(3, 2, &[(0, 0), (1, 0), (1, 1), (2, 1)]);
        // removing the bridging instance splits the graph
        g.remove(super::super::NodeId::Instance(1));
        let c = connected_components(&g);
        assert_eq!(c.count(), 2);
        let sizes: Vec<usize> =
            c.comps.iter().map(|(i, f)| i.len() + f.len()).collect();
        assert_eq!(sizes, vec![2, 2]);
    }

    #[test]
    fn empty_graph() {
        let g = graph_from_edges(0, 0, &[]);
        let c = connected_components(&g);
        assert_eq!(c.count(), 0);
        assert_eq!(c.giant, None);
    }
}
