//! Degree statistics — the paper's Figure 1 (log-log degree distributions)
//! and the skewness evidence that motivates FastPI.

/// Summary statistics of a degree sequence.
#[derive(Debug, Clone)]
pub struct DegreeStats {
    pub count: usize,
    pub min: usize,
    pub max: usize,
    pub mean: f64,
    pub median: usize,
    /// Gini coefficient of the degree mass — 0 uniform, → 1 extreme skew.
    pub gini: f64,
    /// fraction of edges covered by the top 1% highest-degree nodes
    pub top1pct_edge_share: f64,
}

impl DegreeStats {
    pub fn from_degrees(degrees: &[usize]) -> DegreeStats {
        if degrees.is_empty() {
            return DegreeStats {
                count: 0,
                min: 0,
                max: 0,
                mean: 0.0,
                median: 0,
                gini: 0.0,
                top1pct_edge_share: 0.0,
            };
        }
        let mut d: Vec<usize> = degrees.to_vec();
        d.sort_unstable();
        let n = d.len();
        let total: usize = d.iter().sum();
        let mean = total as f64 / n as f64;
        // Gini from the sorted sequence
        let gini = if total == 0 {
            0.0
        } else {
            let weighted: f64 =
                d.iter().enumerate().map(|(i, &x)| (2.0 * (i as f64 + 1.0) - n as f64 - 1.0) * x as f64).sum();
            weighted / (n as f64 * total as f64)
        };
        let top = (n as f64 * 0.01).ceil() as usize;
        let top_edges: usize = d[n - top.max(1)..].iter().sum();
        DegreeStats {
            count: n,
            min: d[0],
            max: d[n - 1],
            mean,
            median: d[n / 2],
            gini,
            top1pct_edge_share: if total == 0 { 0.0 } else { top_edges as f64 / total as f64 },
        }
    }
}

/// Log-binned degree histogram: (bin lower edge, bin upper edge, count).
/// Bins grow geometrically by factor 2 starting at degree 1; degree-0 nodes
/// are reported in a leading (0,0,count) bin. This is the series Figure 1
/// plots on log-log axes.
pub fn log_binned_histogram(degrees: &[usize]) -> Vec<(usize, usize, usize)> {
    let max = degrees.iter().copied().max().unwrap_or(0);
    let zero = degrees.iter().filter(|&&d| d == 0).count();
    let mut bins: Vec<(usize, usize, usize)> = Vec::new();
    if zero > 0 {
        bins.push((0, 0, zero));
    }
    let mut lo = 1usize;
    while lo <= max {
        let hi = lo * 2 - 1;
        let count = degrees.iter().filter(|&&d| d >= lo && d <= hi).count();
        if count > 0 {
            bins.push((lo, hi, count));
        }
        lo *= 2;
    }
    bins
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_uniform_vs_skewed() {
        let uniform = vec![5usize; 100];
        let su = DegreeStats::from_degrees(&uniform);
        assert!((su.gini).abs() < 1e-9);
        assert_eq!(su.median, 5);
        assert_eq!(su.max, 5);

        // skewed: one hub with 1000 edges, 99 nodes with 1
        let mut skewed = vec![1usize; 99];
        skewed.push(1000);
        let ss = DegreeStats::from_degrees(&skewed);
        assert!(ss.gini > 0.8, "gini {}", ss.gini);
        assert!(ss.top1pct_edge_share > 0.9);
        assert_eq!(ss.median, 1);
    }

    #[test]
    fn histogram_covers_all_nodes() {
        let degrees = vec![0, 1, 1, 2, 3, 4, 8, 9, 100];
        let bins = log_binned_histogram(&degrees);
        let total: usize = bins.iter().map(|b| b.2).sum();
        assert_eq!(total, degrees.len());
        // bin edges double
        assert_eq!(bins[0], (0, 0, 1));
        assert_eq!(bins[1], (1, 1, 2));
        assert_eq!(bins[2], (2, 3, 2));
        assert_eq!(bins[3], (4, 7, 1));
    }

    #[test]
    fn empty_degrees() {
        let s = DegreeStats::from_degrees(&[]);
        assert_eq!(s.count, 0);
        assert!(log_binned_histogram(&[]).is_empty());
    }
}
