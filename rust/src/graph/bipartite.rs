//! Bipartite network derived from a feature matrix (paper Definition 1).
//!
//! Rows of `A` are *instance* nodes (V_T) and columns are *feature* nodes
//! (V_F); every non-zero `a_ij` is an edge (i, j). The reordering algorithm
//! removes nodes iteratively, so the graph supports an "alive" mask instead
//! of physically deleting adjacency.

use crate::sparse::Csr;

/// A node in the bipartite graph: either an instance (row) or feature (col).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NodeId {
    Instance(usize),
    Feature(usize),
}

/// Adjacency of the bipartite graph with O(1) degree queries under deletion.
#[derive(Debug, Clone)]
pub struct Bipartite {
    /// instance -> feature adjacency (CSR of A's pattern)
    inst_adj: Vec<Vec<usize>>,
    /// feature -> instance adjacency
    feat_adj: Vec<Vec<usize>>,
    /// alive masks
    inst_alive: Vec<bool>,
    feat_alive: Vec<bool>,
    /// live degrees (decremented on neighbor removal)
    inst_deg: Vec<usize>,
    feat_deg: Vec<usize>,
    live_insts: usize,
    live_feats: usize,
}

impl Bipartite {
    /// Build from the sparsity pattern of `a`.
    pub fn from_csr(a: &Csr) -> Self {
        let (m, n) = a.shape();
        let mut inst_adj: Vec<Vec<usize>> = vec![Vec::new(); m];
        let mut feat_adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        for i in 0..m {
            let (js, _) = a.row(i);
            inst_adj[i].extend_from_slice(js);
            for &j in js {
                feat_adj[j].push(i);
            }
        }
        let inst_deg: Vec<usize> = inst_adj.iter().map(|v| v.len()).collect();
        let feat_deg: Vec<usize> = feat_adj.iter().map(|v| v.len()).collect();
        Bipartite {
            inst_adj,
            feat_adj,
            inst_alive: vec![true; m],
            feat_alive: vec![true; n],
            inst_deg,
            feat_deg,
            live_insts: m,
            live_feats: n,
        }
    }

    pub fn num_instances(&self) -> usize {
        self.inst_adj.len()
    }
    pub fn num_features(&self) -> usize {
        self.feat_adj.len()
    }
    pub fn live_instances(&self) -> usize {
        self.live_insts
    }
    pub fn live_features(&self) -> usize {
        self.live_feats
    }

    pub fn is_alive(&self, node: NodeId) -> bool {
        match node {
            NodeId::Instance(i) => self.inst_alive[i],
            NodeId::Feature(j) => self.feat_alive[j],
        }
    }

    /// Live degree of a node.
    pub fn degree(&self, node: NodeId) -> usize {
        match node {
            NodeId::Instance(i) => self.inst_deg[i],
            NodeId::Feature(j) => self.feat_deg[j],
        }
    }

    /// Live instance degrees (index = row id; dead nodes report 0).
    pub fn instance_degrees(&self) -> &[usize] {
        &self.inst_deg
    }
    pub fn feature_degrees(&self) -> &[usize] {
        &self.feat_deg
    }

    /// Iterate live feature neighbors of instance i.
    pub fn instance_neighbors(&self, i: usize) -> impl Iterator<Item = usize> + '_ {
        self.inst_adj[i].iter().copied().filter(|&j| self.feat_alive[j])
    }

    /// Iterate live instance neighbors of feature j.
    pub fn feature_neighbors(&self, j: usize) -> impl Iterator<Item = usize> + '_ {
        self.feat_adj[j].iter().copied().filter(|&i| self.inst_alive[i])
    }

    /// Remove a node: mark dead and decrement live neighbor degrees.
    pub fn remove(&mut self, node: NodeId) {
        match node {
            NodeId::Instance(i) => {
                if !self.inst_alive[i] {
                    return;
                }
                self.inst_alive[i] = false;
                self.live_insts -= 1;
                self.inst_deg[i] = 0;
                for idx in 0..self.inst_adj[i].len() {
                    let j = self.inst_adj[i][idx];
                    if self.feat_alive[j] {
                        self.feat_deg[j] -= 1;
                    }
                }
            }
            NodeId::Feature(j) => {
                if !self.feat_alive[j] {
                    return;
                }
                self.feat_alive[j] = false;
                self.live_feats -= 1;
                self.feat_deg[j] = 0;
                for idx in 0..self.feat_adj[j].len() {
                    let i = self.feat_adj[j][idx];
                    if self.inst_alive[i] {
                        self.inst_deg[i] -= 1;
                    }
                }
            }
        }
    }

    /// Live instance ids.
    pub fn live_instance_ids(&self) -> Vec<usize> {
        (0..self.num_instances()).filter(|&i| self.inst_alive[i]).collect()
    }

    /// Live feature ids.
    pub fn live_feature_ids(&self) -> Vec<usize> {
        (0..self.num_features()).filter(|&j| self.feat_alive[j]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    fn tiny() -> Bipartite {
        // A: 3 instances x 2 features
        // edges: (0,0), (1,0), (1,1), (2,1)
        let mut coo = Coo::new(3, 2);
        coo.push(0, 0, 1.0);
        coo.push(1, 0, 1.0);
        coo.push(1, 1, 1.0);
        coo.push(2, 1, 1.0);
        Bipartite::from_csr(&Csr::from_coo(&coo))
    }

    #[test]
    fn degrees_from_pattern() {
        let g = tiny();
        assert_eq!(g.degree(NodeId::Instance(1)), 2);
        assert_eq!(g.degree(NodeId::Feature(0)), 2);
        assert_eq!(g.degree(NodeId::Feature(1)), 2);
        assert_eq!(g.live_instances(), 3);
        assert_eq!(g.live_features(), 2);
    }

    #[test]
    fn removal_updates_neighbors() {
        let mut g = tiny();
        g.remove(NodeId::Feature(0));
        assert!(!g.is_alive(NodeId::Feature(0)));
        assert_eq!(g.degree(NodeId::Instance(0)), 0);
        assert_eq!(g.degree(NodeId::Instance(1)), 1);
        assert_eq!(g.live_features(), 1);
        // idempotent
        g.remove(NodeId::Feature(0));
        assert_eq!(g.live_features(), 1);
        // neighbor iteration skips dead
        let nb: Vec<usize> = g.instance_neighbors(1).collect();
        assert_eq!(nb, vec![1]);
    }

    #[test]
    fn remove_instance_side() {
        let mut g = tiny();
        g.remove(NodeId::Instance(1));
        assert_eq!(g.degree(NodeId::Feature(0)), 1);
        assert_eq!(g.degree(NodeId::Feature(1)), 1);
        assert_eq!(g.live_instance_ids(), vec![0, 2]);
    }
}
