//! Bipartite-graph view of a sparse feature matrix (paper Definition 1) and
//! the graph algorithms Algorithm 2 needs: degree statistics and BFS
//! connected components over the union of instance and feature nodes.

pub mod bipartite;
pub mod components;
pub mod degree;

pub use bipartite::{Bipartite, NodeId};
pub use components::{connected_components, Components};
pub use degree::{log_binned_histogram, DegreeStats};
