//! FastPI — Algorithm 1 of the paper, end to end.
//!
//! 1. reorder A with Algorithm 2 and split into [[A11 A12],[A21 A22]]
//! 2. SVD of the block-diagonal A11 at rank s = ⌈α·n1⌉ (Eq. 1)
//! 3. incremental row update folding in A21 (Eq. 2)
//! 4. incremental column update folding in T = [A12; A22] (Eq. 3)
//! 5. pseudoinverse A† = V Σ† Uᵀ (Problem 1)
//!
//! The SVD factors are returned in the ORIGINAL coordinate system (the
//! permutations are folded back into U and Vᵀ), so callers never see the
//! reordering.

use super::Pinv;
use crate::dense::{Matrix, Svd};
use crate::error::Result;
use crate::reorder::{reorder, ReorderConfig, Reordering};
use crate::sparse::Csr;
use crate::svdlr::{block_diag_svd, update_cols, update_rows, InnerSvd, LowRankEngine};
use crate::util::rng::Rng;
use crate::util::timer::StageTimes;

/// FastPI parameters.
#[derive(Debug, Clone)]
pub struct FastPiConfig {
    /// target rank ratio α ∈ (0, 1]; target rank r = ⌈α·n⌉
    pub alpha: f64,
    /// hub selection ratio for Algorithm 2 (paper: 0.01)
    pub k: f64,
    /// inner SVD engine for the incremental updates (paper: Auto)
    pub inner: InnerSvd,
    /// cap on reordering iterations
    pub max_reorder_iters: usize,
}

impl Default for FastPiConfig {
    fn default() -> Self {
        FastPiConfig { alpha: 0.3, k: 0.01, inner: InnerSvd::Auto, max_reorder_iters: 1000 }
    }
}

/// Everything FastPI produces: the low-rank SVD (original coordinates), the
/// reordering diagnostics, and per-stage timings.
#[derive(Debug)]
pub struct FastPiOutput {
    pub svd: Svd,
    pub reordering: Reordering,
    pub times: StageTimes,
}

impl FastPiOutput {
    /// Construct the factored pseudoinverse (line 5 / Problem 1).
    pub fn pinv(&self) -> Pinv {
        Pinv::from_svd(&self.svd)
    }
}

/// Run Algorithm 1 on `a`.
pub fn fastpi_svd(a: &Csr, cfg: &FastPiConfig, rng: &mut Rng) -> Result<FastPiOutput> {
    assert!(cfg.alpha > 0.0 && cfg.alpha <= 1.0, "alpha must be in (0,1]");
    let (m, n) = a.shape();
    let mut times = StageTimes::new();

    // --- line 1: reorder and split
    let reordering = times.time("reorder", || {
        reorder(a, &ReorderConfig { k: cfg.k, max_iters: cfg.max_reorder_iters })
    });
    let b = times.time("permute", || reordering.apply(a));
    let (m1, n1) = (reordering.m1, reordering.n1);
    let (m2, n2) = (reordering.m2, reordering.n2);

    // Degenerate: a matrix with no rows or no columns has the unique empty
    // SVD (and the rank-target clamp below would be ill-formed, min > max).
    if m == 0 || n == 0 {
        let svd = Svd { u: Matrix::zeros(m, 0), s: vec![], vt: Matrix::zeros(0, n) };
        return Ok(FastPiOutput { svd, reordering, times });
    }

    // --- line 2: SVD of the block-diagonal A11 (Eq. 1)
    let mut f = times.time("block_svd(A11)", || {
        block_diag_svd(&b, &reordering.blocks, m1, n1, cfg.alpha)
    });

    // --- line 3: fold in the hub rows A21 (Eq. 2), target s = ⌈α·n1⌉
    if m2 > 0 && n1 > 0 {
        let s_target = ((cfg.alpha * n1 as f64).ceil() as usize).clamp(1, n1.min(m));
        let a21 = b.block(m1, 0, m2, n1);
        f = times.time("update_rows(A21)", || update_rows(&f, &a21, s_target, cfg.inner, rng));
    } else if n1 > 0 && f.u.rows() < m {
        // no hub rows: U already spans all m1 = m rows
        debug_assert_eq!(f.u.rows(), m);
    }

    // --- line 4: fold in the hub columns T = [A12; A22] (Eq. 3), r = ⌈α·n⌉
    let r_target = ((cfg.alpha * n as f64).ceil() as usize).clamp(1, m.min(n));
    if n2 > 0 {
        let t = b.block(0, n1, m, n2);
        if n1 == 0 || f.rank() == 0 {
            // degenerate: nothing shattered (A11 empty, or every spoke
            // block was structurally zero) — the "incremental" SVD is just
            // the SVD of T itself. That SVD only spans the n2 hub columns;
            // when n1 > 0 the leading spoke columns are all-zero here (a
            // rank-0 left part), so Vᵀ is re-embedded with zero columns in
            // the 0..n1 range to restore the full n-column coordinate
            // system that the unpermute step below requires.
            let t_dense = t.to_dense();
            f = times.time("update_cols(T)", || cfg.inner.run(&t_dense, r_target, rng));
            if n1 > 0 {
                let mut vt = Matrix::zeros(f.rank(), n);
                vt.set_submatrix(0, n1, &f.vt);
                f = Svd { u: f.u, s: f.s, vt };
            }
        } else {
            f = times.time("update_cols(T)", || update_cols(&f, &t, r_target, cfg.inner, rng));
        }
    } else if f.rank() > r_target {
        f = f.truncate(r_target);
    }

    // --- map factors back to the original coordinates:
    // B = P_r A P_cᵀ = U Σ Vᵀ  ⇒  A = (P_rᵀU) Σ (VᵀP_c)
    let svd = times.time("unpermute", || Svd {
        u: unpermute_rows(&f.u, &reordering.row_perm),
        s: f.s,
        vt: unpermute_cols(&f.vt, &reordering.col_perm),
    });

    Ok(FastPiOutput { svd, reordering, times })
}

/// U_a[old_row] = U_b[row_perm[old_row]].
fn unpermute_rows(u: &Matrix, row_perm: &[usize]) -> Matrix {
    assert_eq!(u.rows(), row_perm.len());
    let mut out = Matrix::zeros(u.rows(), u.cols());
    for (old, &new) in row_perm.iter().enumerate() {
        out.row_mut(old).copy_from_slice(u.row(new));
    }
    out
}

/// Vt_a[:, old_col] = Vt_b[:, col_perm[old_col]].
fn unpermute_cols(vt: &Matrix, col_perm: &[usize]) -> Matrix {
    assert_eq!(vt.cols(), col_perm.len());
    let mut out = Matrix::zeros(vt.rows(), vt.cols());
    for i in 0..vt.rows() {
        let src = vt.row(i);
        let dst = out.row_mut(i);
        for (old, &new) in col_perm.iter().enumerate() {
            dst[old] = src[new];
        }
    }
    out
}

/// FastPI as a [`LowRankEngine`], for uniform benchmarking against the
/// competitors. The rank is translated to α = rank/n.
#[derive(Debug, Clone)]
pub struct FastPiEngine {
    pub k: f64,
    pub inner: InnerSvd,
}

impl Default for FastPiEngine {
    fn default() -> Self {
        FastPiEngine { k: 0.01, inner: InnerSvd::Auto }
    }
}

impl LowRankEngine for FastPiEngine {
    fn name(&self) -> &'static str {
        "FastPI"
    }

    fn factorize(&self, a: &Csr, rank: usize, rng: &mut Rng) -> Result<Svd> {
        let n = a.cols().max(1);
        let alpha = (rank as f64 / n as f64).clamp(f64::MIN_POSITIVE, 1.0);
        let cfg = FastPiConfig { alpha, k: self.k, inner: self.inner, ..Default::default() };
        Ok(fastpi_svd(a, &cfg, rng)?.svd)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dense::qr::orthogonality_defect;
    use crate::dense::svd as dense_svd;
    use crate::sparse::Coo;
    use crate::util::propcheck::check;

    /// Skewed sparse test matrix (hub-and-spoke structure).
    pub(crate) fn skewed(rng: &mut Rng, m: usize, n: usize, nnz: usize) -> Csr {
        let wi: Vec<f64> = (0..m).map(|_| rng.power_law(2.0, m as f64)).collect();
        let wf: Vec<f64> = (0..n).map(|_| rng.power_law(2.0, n as f64)).collect();
        let cum = |w: &[f64]| {
            let mut c = Vec::with_capacity(w.len());
            let mut s = 0.0;
            for &x in w {
                s += x;
                c.push(s);
            }
            c
        };
        let (ci, cf) = (cum(&wi), cum(&wf));
        let mut coo = Coo::new(m, n);
        for _ in 0..nnz {
            coo.push(rng.sample_cumulative(&ci), rng.sample_cumulative(&cf), 1.0 + rng.f64());
        }
        Csr::from_coo(&coo)
    }

    #[test]
    fn full_alpha_reconstructs() {
        check("FastPI exact at alpha=1", 8, |rng| {
            let (m, n) = (rng.usize_range(20, 60), rng.usize_range(10, 30));
            let a = skewed(rng, m, n, 3 * (m + n));
            let cfg = FastPiConfig { alpha: 1.0, k: 0.05, inner: InnerSvd::Dense, ..Default::default() };
            let out = fastpi_svd(&a, &cfg, rng).unwrap();
            let dense = a.to_dense();
            let scale = dense.fro_norm().max(1.0);
            assert!(
                out.svd.reconstruction_error(&dense) / scale < 1e-8,
                "err {} m={m} n={n}",
                out.svd.reconstruction_error(&dense)
            );
            assert!(orthogonality_defect(&out.svd.u) < 1e-8, "U orth");
            assert!(orthogonality_defect(&out.svd.vt.transpose()) < 1e-8, "V orth");
        });
    }

    #[test]
    fn partial_alpha_near_optimal() {
        check("FastPI near-optimal at partial alpha", 6, |rng| {
            let (m, n) = (rng.usize_range(30, 70), rng.usize_range(15, 35));
            let a = skewed(rng, m, n, 4 * (m + n));
            let alpha = rng.f64_range(0.3, 0.9);
            let cfg = FastPiConfig { alpha, k: 0.05, inner: InnerSvd::Dense, ..Default::default() };
            let out = fastpi_svd(&a, &cfg, rng).unwrap();
            let dense = a.to_dense();
            let exact = dense_svd(&dense);
            let r = out.svd.rank();
            let best: f64 = exact.s[r.min(exact.s.len())..].iter().map(|x| x * x).sum::<f64>().sqrt();
            let got = out.svd.reconstruction_error(&dense);
            // FastPI is an approximation built from truncated pieces: allow
            // modest suboptimality but require the same order of magnitude
            let scale = dense.fro_norm().max(1.0);
            assert!(
                (got - best) / scale < 0.2,
                "alpha={alpha} got {got} best {best} scale {scale}"
            );
        });
    }

    #[test]
    fn rank_matches_ceil_alpha_n() {
        let mut rng = Rng::seed_from_u64(7);
        let a = skewed(&mut rng, 80, 40, 400);
        for alpha in [0.1, 0.25, 0.5, 1.0] {
            let cfg = FastPiConfig { alpha, k: 0.05, inner: InnerSvd::Dense, ..Default::default() };
            let out = fastpi_svd(&a, &cfg, &mut rng).unwrap();
            let expect = ((alpha * 40.0).ceil() as usize).min(40);
            assert_eq!(out.svd.rank(), expect, "alpha={alpha}");
        }
    }

    #[test]
    fn pinv_of_fastpi_solves_regression() {
        let mut rng = Rng::seed_from_u64(8);
        let a = skewed(&mut rng, 50, 20, 300);
        let cfg = FastPiConfig { alpha: 1.0, k: 0.05, inner: InnerSvd::Dense, ..Default::default() };
        let out = fastpi_svd(&a, &cfg, &mut rng).unwrap();
        let p = out.pinv();
        // consistent system: A z0 = y recovers the minimum-norm solution
        let dense = a.to_dense();
        let exact_p = Pinv::from_svd(&dense_svd(&dense));
        let y = Matrix::randn(50, 3, &mut rng);
        let z_fast = p.apply(&y);
        let z_exact = exact_p.apply(&y);
        assert!(z_fast.max_abs_diff(&z_exact) < 1e-6, "pinv apply mismatch");
    }

    #[test]
    fn stage_times_recorded() {
        let mut rng = Rng::seed_from_u64(9);
        let a = skewed(&mut rng, 60, 30, 300);
        let out = fastpi_svd(&a, &FastPiConfig::default(), &mut rng).unwrap();
        let stages: Vec<String> = out.times.rows().iter().map(|(n, _)| n.clone()).collect();
        assert!(stages.iter().any(|s| s == "reorder"));
        assert!(stages.iter().any(|s| s.starts_with("block_svd")));
    }

    #[test]
    fn engine_wrapper_consistent() {
        let mut rng = Rng::seed_from_u64(10);
        let a = skewed(&mut rng, 40, 20, 200);
        let f = FastPiEngine::default().factorize(&a, 10, &mut rng).unwrap();
        assert_eq!(f.rank(), 10);
    }

    #[test]
    fn bitwise_identical_across_thread_counts() {
        // The worker pool distributes dynamically but every output element
        // has one owner and a fixed reduction order, so the full pipeline —
        // reorder → parallel block SVDs → incremental updates (pool GEMMs +
        // panel-reduced Gram products) — must produce bitwise-identical
        // factors at 1 and 4 threads. The rng is owned by the caller and
        // never shared across workers, so it advances identically too.
        let a = {
            let mut rng = Rng::seed_from_u64(77);
            skewed(&mut rng, 120, 60, 700)
        };
        let cfg = FastPiConfig { alpha: 0.4, k: 0.05, ..Default::default() };
        let serial = crate::runtime::pool::with_thread_cap(1, || {
            fastpi_svd(&a, &cfg, &mut Rng::seed_from_u64(5)).unwrap()
        });
        let parallel = crate::runtime::pool::with_thread_cap(4, || {
            fastpi_svd(&a, &cfg, &mut Rng::seed_from_u64(5)).unwrap()
        });
        assert_eq!(serial.svd.s, parallel.svd.s, "singular values drifted");
        assert_eq!(serial.svd.u, parallel.svd.u, "U drifted");
        assert_eq!(serial.svd.vt, parallel.svd.vt, "Vᵀ drifted");
    }

    #[test]
    fn zero_a11_blocks_still_produce_full_coordinates() {
        // Degree-zero rows and columns become structurally-zero spoke
        // blocks after reordering (n1 > 0 with every A11 block skipped).
        // The pipeline must still return factors in the full m×n coordinate
        // system and reconstruct the matrix exactly at α = 1.
        let mut coo = Coo::new(6, 5);
        // dense hub: rows 0..4 × cols 0..3 fully populated
        for i in 0..4 {
            for j in 0..3 {
                coo.push(i, j, 1.0 + (i * 3 + j) as f64);
            }
        }
        // isolated instance rows 4,5 and isolated feature cols 3,4 carry no
        // entries at all — they become zero spoke blocks after reordering
        let a = Csr::from_coo(&coo);
        let mut rng = Rng::seed_from_u64(13);
        let cfg = FastPiConfig { alpha: 1.0, k: 0.3, inner: InnerSvd::Dense, ..Default::default() };
        let out = fastpi_svd(&a, &cfg, &mut rng).unwrap();
        assert_eq!(out.svd.u.rows(), 6);
        assert_eq!(out.svd.vt.cols(), 5);
        let dense = a.to_dense();
        assert!(
            out.svd.reconstruction_error(&dense) < 1e-9 * dense.fro_norm().max(1.0),
            "err {}",
            out.svd.reconstruction_error(&dense)
        );
    }

    #[test]
    fn empty_matrix_degenerates_cleanly() {
        let a = Csr::zeros(0, 7);
        let mut rng = Rng::seed_from_u64(1);
        let out = fastpi_svd(&a, &FastPiConfig::default(), &mut rng).unwrap();
        assert_eq!(out.svd.rank(), 0);
        assert_eq!(out.svd.vt.cols(), 7);
        let b = Csr::zeros(4, 0);
        let out = fastpi_svd(&b, &FastPiConfig::default(), &mut rng).unwrap();
        assert_eq!(out.svd.rank(), 0);
        assert_eq!(out.svd.u.rows(), 4);
    }

    #[test]
    fn degenerate_dense_matrix() {
        // Fully dense small matrix: nothing shatters; FastPI must still
        // return a valid SVD via the degenerate path.
        let mut rng = Rng::seed_from_u64(11);
        let dense = Matrix::randn(12, 8, &mut rng);
        let mut coo = Coo::new(12, 8);
        for i in 0..12 {
            for j in 0..8 {
                coo.push(i, j, dense[(i, j)]);
            }
        }
        let a = Csr::from_coo(&coo);
        let cfg = FastPiConfig { alpha: 1.0, k: 0.1, inner: InnerSvd::Dense, ..Default::default() };
        let out = fastpi_svd(&a, &cfg, &mut rng).unwrap();
        assert!(
            out.svd.reconstruction_error(&dense) < 1e-7 * dense.fro_norm(),
            "err {}",
            out.svd.reconstruction_error(&dense)
        );
    }
}
