//! Pseudoinverse construction and the FastPI pipeline (Algorithm 1).

pub mod baselines;
pub mod fastpi;

pub use baselines::{low_rank_svd, Method};
pub use fastpi::{fastpi_svd, FastPiConfig, FastPiOutput};

use crate::dense::{matmul, Matrix, Svd};
use crate::sparse::Csr;

/// Factored Moore–Penrose pseudoinverse `A† = V Σ† Uᵀ` (Problem 1).
///
/// Kept in factored form: applying it to a matrix/vector is
/// O((m+n)r·width) instead of materializing the n×m dense inverse.
#[derive(Debug, Clone)]
pub struct Pinv {
    /// V (n×r)
    pub v: Matrix,
    /// reciprocal singular values with rank cutoff applied (σ < tol ↦ 0)
    pub s_inv: Vec<f64>,
    /// Uᵀ (r×m)
    pub ut: Matrix,
}

impl Pinv {
    /// Build from a (possibly truncated) SVD. Singular values below
    /// `rcond · σ_max` are treated as zero (standard pinv cutoff).
    pub fn from_svd(f: &Svd) -> Pinv {
        Self::from_svd_rcond(f, 1e-12)
    }

    /// Build with an explicit relative cutoff.
    pub fn from_svd_rcond(f: &Svd, rcond: f64) -> Pinv {
        let smax = f.s.first().copied().unwrap_or(0.0);
        let tol = smax * rcond;
        let s_inv: Vec<f64> =
            f.s.iter().map(|&x| if x > tol && x > 0.0 { 1.0 / x } else { 0.0 }).collect();
        Pinv { v: f.vt.transpose(), s_inv, ut: f.u.transpose() }
    }

    pub fn rank(&self) -> usize {
        self.s_inv.iter().filter(|&&x| x != 0.0).count()
    }

    /// Rows of A (m) and columns of A (n) this pseudoinverse corresponds to.
    pub fn input_shape(&self) -> (usize, usize) {
        (self.ut.cols(), self.v.rows())
    }

    /// Apply to a dense matrix: X = A†·Y = V·(Σ†·(Uᵀ·Y)).
    pub fn apply(&self, y: &Matrix) -> Matrix {
        let uty = matmul(&self.ut, y); // r×w
        let scaled = uty.scale_rows(&self.s_inv);
        matmul(&self.v, &scaled) // n×w
    }

    /// Apply to a sparse matrix (e.g. a sparse label matrix Y):
    /// computes Uᵀ·Y sparse-side, then proceeds dense.
    pub fn apply_sparse(&self, y: &Csr) -> Matrix {
        // Uᵀ·Y = (Yᵀ·U)ᵀ
        let u = self.ut.transpose();
        let uty = y.spmm_t(&u).transpose(); // r×L
        let scaled = uty.scale_rows(&self.s_inv);
        matmul(&self.v, &scaled)
    }

    /// Apply to a single vector.
    pub fn apply_vec(&self, y: &[f64]) -> Vec<f64> {
        let uty = self.ut.matvec(y);
        let scaled: Vec<f64> = uty.iter().zip(&self.s_inv).map(|(x, s)| x * s).collect();
        self.v.matvec(&scaled)
    }

    /// Materialize the dense n×m pseudoinverse (tests / tiny matrices only).
    pub fn to_dense(&self) -> Matrix {
        matmul(&self.v.scale_cols(&self.s_inv), &self.ut)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dense::svd;
    use crate::util::propcheck::check;
    use crate::util::rng::Rng;

    /// Verify the four Moore–Penrose conditions on dense matrices.
    fn check_moore_penrose(a: &Matrix, pinv: &Matrix, tol: f64) {
        let ap = matmul(a, pinv); // m×m
        let pa = matmul(pinv, a); // n×n
        // 1) A A† A = A
        assert!(matmul(&ap, a).max_abs_diff(a) < tol, "MP1");
        // 2) A† A A† = A†
        assert!(matmul(&pa, pinv).max_abs_diff(pinv) < tol, "MP2");
        // 3) (A A†)ᵀ = A A†
        assert!(ap.transpose().max_abs_diff(&ap) < tol, "MP3");
        // 4) (A† A)ᵀ = A† A
        assert!(pa.transpose().max_abs_diff(&pa) < tol, "MP4");
    }

    #[test]
    fn moore_penrose_conditions_full_rank() {
        check("pinv satisfies Moore-Penrose", 15, |rng: &mut Rng| {
            let n = rng.usize_range(1, 12);
            let m = n + rng.usize_range(0, 10);
            let a = Matrix::randn(m, n, rng);
            let p = Pinv::from_svd(&svd(&a)).to_dense();
            check_moore_penrose(&a, &p, 1e-7);
        });
    }

    #[test]
    fn moore_penrose_conditions_rank_deficient() {
        check("pinv MP on rank-deficient", 10, |rng: &mut Rng| {
            let r = rng.usize_range(1, 5);
            let m = r + rng.usize_range(2, 12);
            let n = r + rng.usize_range(1, 8);
            let b = Matrix::randn(m, r, rng);
            let c = Matrix::randn(r, n, rng);
            let a = matmul(&b, &c);
            let p = Pinv::from_svd(&svd(&a)).to_dense();
            check_moore_penrose(&a, &p, 1e-6);
        });
    }

    #[test]
    fn least_squares_solution() {
        // Z = A†y minimizes ||Az - y||; for consistent systems it solves exactly.
        let mut rng = Rng::seed_from_u64(3);
        let a = Matrix::randn(20, 8, &mut rng);
        let z0 = rng.normal_vec(8);
        let y = a.matvec(&z0);
        let p = Pinv::from_svd(&svd(&a));
        let z = p.apply_vec(&y);
        for i in 0..8 {
            assert!((z[i] - z0[i]).abs() < 1e-8, "z[{i}]");
        }
    }

    #[test]
    fn apply_variants_agree() {
        let mut rng = Rng::seed_from_u64(4);
        let a = Matrix::randn(15, 6, &mut rng);
        let p = Pinv::from_svd(&svd(&a));
        let y = Matrix::randn(15, 4, &mut rng);
        let dense_apply = p.apply(&y);
        let explicit = matmul(&p.to_dense(), &y);
        assert!(dense_apply.max_abs_diff(&explicit) < 1e-10);
        // sparse path
        let mut coo = crate::sparse::Coo::new(15, 4);
        for i in 0..15 {
            for j in 0..4 {
                if y[(i, j)] > 0.5 {
                    coo.push(i, j, y[(i, j)]);
                }
            }
        }
        let ys = Csr::from_coo(&coo);
        let sparse_apply = p.apply_sparse(&ys);
        let explicit2 = matmul(&p.to_dense(), &ys.to_dense());
        assert!(sparse_apply.max_abs_diff(&explicit2) < 1e-10);
        // vector path
        let yv = y.col(0);
        let zv = p.apply_vec(&yv);
        for i in 0..6 {
            assert!((zv[i] - dense_apply[(i, 0)]).abs() < 1e-10);
        }
    }

    #[test]
    fn cutoff_zeroes_tiny_sigmas() {
        let f = Svd {
            u: Matrix::eye(3),
            s: vec![1.0, 1e-20, 0.0],
            vt: Matrix::eye(3),
        };
        let p = Pinv::from_svd(&f);
        assert_eq!(p.rank(), 1);
        assert_eq!(p.s_inv[1], 0.0);
        assert_eq!(p.s_inv[2], 0.0);
    }
}
