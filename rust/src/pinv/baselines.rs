//! Uniform access to all pseudoinverse methods — the experiment harnesses
//! sweep over these.

use super::fastpi::FastPiEngine;
use crate::dense::Svd;
use crate::error::Result;
use crate::sparse::Csr;
use crate::svdlr::{DenseEngine, FrPcaEngine, KrylovEngine, LowRankEngine, RandomizedEngine};
use crate::util::rng::Rng;

/// The methods compared in the paper's evaluation (plus the dense oracle).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    FastPi,
    RandPi,
    KrylovPi,
    FrPca,
    Dense,
}

impl Method {
    pub const PAPER_SET: [Method; 4] =
        [Method::FastPi, Method::RandPi, Method::KrylovPi, Method::FrPca];

    pub fn name(&self) -> &'static str {
        match self {
            Method::FastPi => "FastPI",
            Method::RandPi => "RandPI",
            Method::KrylovPi => "KrylovPI",
            Method::FrPca => "frPCA",
            Method::Dense => "DenseSVD",
        }
    }

    pub fn from_name(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "fastpi" => Some(Method::FastPi),
            "randpi" => Some(Method::RandPi),
            "krylovpi" | "krylov" => Some(Method::KrylovPi),
            "frpca" => Some(Method::FrPca),
            "dense" | "densesvd" => Some(Method::Dense),
            _ => None,
        }
    }

    pub fn engine(&self) -> Box<dyn LowRankEngine> {
        match self {
            Method::FastPi => Box::new(FastPiEngine::default()),
            Method::RandPi => Box::new(RandomizedEngine::default()),
            Method::KrylovPi => Box::new(KrylovEngine::default()),
            Method::FrPca => Box::new(FrPcaEngine::default()),
            Method::Dense => Box::new(DenseEngine),
        }
    }
}

/// Compute the rank-⌈α·n⌉ SVD of `a` with the given method; returns the
/// factorization and the wall-clock seconds it took (the Figure-6 metric).
pub fn low_rank_svd(method: Method, a: &Csr, alpha: f64, seed: u64) -> Result<(Svd, f64)> {
    let n = a.cols();
    let rank = ((alpha * n as f64).ceil() as usize).clamp(1, a.rows().min(n));
    let engine = method.engine();
    let mut rng = Rng::seed_from_u64(seed);
    let t = std::time::Instant::now();
    let f = engine.factorize(a, rank, &mut rng)?;
    Ok((f, t.elapsed().as_secs_f64()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svdlr::testutil::random_sparse;

    #[test]
    fn names_roundtrip() {
        for m in Method::PAPER_SET {
            assert_eq!(Method::from_name(m.name()), Some(m));
        }
        assert_eq!(Method::from_name("nope"), None);
    }

    #[test]
    fn all_methods_factorize() {
        let mut rng = Rng::seed_from_u64(1);
        let a = random_sparse(&mut rng, 40, 25, 250);
        for m in [Method::FastPi, Method::RandPi, Method::KrylovPi, Method::FrPca, Method::Dense] {
            let (f, secs) = low_rank_svd(m, &a, 0.3, 42).unwrap();
            let expect_rank = (0.3f64 * 25.0).ceil() as usize;
            assert_eq!(f.rank(), expect_rank, "{}", m.name());
            assert!(secs >= 0.0);
            // sane reconstruction for every method
            let err = f.reconstruction_error(&a.to_dense());
            assert!(err < a.fro_norm(), "{} error {err}", m.name());
        }
    }

    #[test]
    fn alpha_one_gives_full_rank() {
        let mut rng = Rng::seed_from_u64(2);
        let a = random_sparse(&mut rng, 30, 12, 100);
        let (f, _) = low_rank_svd(Method::Dense, &a, 1.0, 0).unwrap();
        assert_eq!(f.rank(), 12);
    }
}
