//! FastPI command-line interface — leader entrypoint.
//!
//! Subcommands map 1:1 to the paper's experiments (see DESIGN.md §6) plus
//! operational commands (`pinv`, `serve`, `datagen`, `selftest`).

fn main() {
    fastpi::cli::main();
}
