//! Dataset substrate: synthetic generators matched to the paper's Table 3
//! statistics, a registry of the four evaluation datasets, and a binary
//! cache so experiments don't regenerate.
//!
//! The real Amazon/RCV/Eurlex/Bibtex corpora are not available offline; per
//! DESIGN.md §5 we substitute structure-preserving synthetic equivalents:
//! power-law degree-weighted bipartite sampling reproduces the sparsity and
//! hub-and-spoke skew FastPI exploits, and labels are generated from a
//! sparse linear ground truth so the multi-label regression task is
//! genuinely learnable (Figure 5's under/overfit curve appears).

pub mod registry;
pub mod synth;

pub use registry::{load_dataset, Dataset, DatasetSpec, PAPER_DATASETS};
pub use synth::{generate, SynthConfig};
