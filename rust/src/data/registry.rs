//! Registry of the paper's four evaluation datasets (Table 3), with a scale
//! knob and a binary on-disk cache.

use super::synth::{generate, SynthConfig};
use crate::error::Result;
use crate::sparse::{io as sio, Csr};
use crate::util::rng::Rng;
use std::path::{Path, PathBuf};

/// Specification matching a Table-3 row.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub m: usize,
    pub n: usize,
    pub labels: usize,
    pub nnz: usize,
    /// hub selection ratio the paper used for this dataset
    pub k: f64,
}

/// The four paper datasets (Table 3).
pub const PAPER_DATASETS: [DatasetSpec; 4] = [
    DatasetSpec { name: "amazon", m: 59_312, n: 10_195, labels: 13_330, nnz: 167_015, k: 0.01 },
    DatasetSpec { name: "rcv", m: 62_385, n: 4_724, labels: 2_456, nnz: 466_675, k: 0.01 },
    DatasetSpec { name: "eurlex", m: 15_539, n: 5_000, labels: 3_993, nnz: 3_684_773, k: 0.01 },
    DatasetSpec { name: "bibtex", m: 7_395, n: 1_836, labels: 159, nnz: 507_746, k: 0.01 },
];

impl DatasetSpec {
    pub fn by_name(name: &str) -> Option<&'static DatasetSpec> {
        PAPER_DATASETS.iter().find(|d| d.name == name)
    }

    /// Scaled-down spec: dimensions scale by `f`, nnz by `f^1.5` — a
    /// compromise between preserving density (f²) and preserving average
    /// degree (f), keeping the matrix both sparse and connected enough to
    /// exercise the reordering (DESIGN.md §5).
    pub fn scaled(&self, f: f64) -> SynthConfig {
        assert!(f > 0.0 && f <= 1.0);
        let scale_dim = |x: usize| ((x as f64 * f).ceil() as usize).max(4);
        let m = scale_dim(self.m);
        let n = scale_dim(self.n);
        let labels = scale_dim(self.labels).max(8);
        let nnz = ((self.nnz as f64 * f.powf(1.5)).ceil() as usize).min(m * n / 2).max(m);
        SynthConfig { m, n, labels, nnz, ..Default::default() }
    }
}

/// A materialized dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub scale: f64,
    pub a: Csr,
    pub y: Csr,
    pub k: f64,
}

impl Dataset {
    /// Table-3 style statistics row: (m, n, L, |A|, sp(A), sp(Y)).
    pub fn stats(&self) -> (usize, usize, usize, usize, f64, f64) {
        (
            self.a.rows(),
            self.a.cols(),
            self.y.cols(),
            self.a.nnz(),
            self.a.sparsity(),
            self.y.sparsity(),
        )
    }
}

/// Default cache directory.
pub fn default_cache_dir() -> PathBuf {
    PathBuf::from("target/datasets")
}

/// Load (or generate + cache) a paper dataset at the given scale and seed.
pub fn load_dataset(name: &str, scale: f64, seed: u64, cache: Option<&Path>) -> Result<Dataset> {
    let spec = DatasetSpec::by_name(name)
        .ok_or_else(|| crate::error::Error::Invalid(format!("unknown dataset `{name}`")))?;
    let cache_dir = cache.map(|p| p.to_path_buf()).unwrap_or_else(default_cache_dir);
    let stem = format!("{name}_s{scale}_seed{seed}");
    let a_path = cache_dir.join(format!("{stem}.a.fpi"));
    let y_path = cache_dir.join(format!("{stem}.y.fpi"));

    if a_path.exists() && y_path.exists() {
        if let (Ok(a), Ok(y)) = (sio::read_binary(&a_path), sio::read_binary(&y_path)) {
            return Ok(Dataset { name: name.to_string(), scale, a, y, k: spec.k });
        }
    }

    let cfg = spec.scaled(scale);
    let mut rng = Rng::seed_from_u64(seed ^ crate::util::hash::fnv1a(name.as_bytes()));
    let (a, y) = generate(&cfg, &mut rng);
    if std::fs::create_dir_all(&cache_dir).is_ok() {
        let _ = sio::write_binary(&a_path, &a);
        let _ = sio::write_binary(&y_path, &y);
    }
    Ok(Dataset { name: name.to_string(), scale, a, y, k: spec.k })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_paper() {
        assert_eq!(PAPER_DATASETS.len(), 4);
        assert!(DatasetSpec::by_name("amazon").is_some());
        assert!(DatasetSpec::by_name("bogus").is_none());
        let rcv = DatasetSpec::by_name("rcv").unwrap();
        assert_eq!(rcv.m, 62_385);
    }

    #[test]
    fn scaled_spec_dimensions() {
        let spec = DatasetSpec::by_name("bibtex").unwrap();
        let cfg = spec.scaled(0.1);
        assert_eq!(cfg.m, 740);
        assert_eq!(cfg.n, 184);
        assert!(cfg.nnz > 0 && cfg.nnz <= cfg.m * cfg.n / 2);
    }

    #[test]
    fn load_generates_and_caches() {
        let dir = std::env::temp_dir().join("fastpi_ds_cache_test");
        let _ = std::fs::remove_dir_all(&dir);
        let d1 = load_dataset("bibtex", 0.05, 7, Some(&dir)).unwrap();
        assert_eq!(d1.a.rows(), 370);
        // second load must come from cache and be identical
        let d2 = load_dataset("bibtex", 0.05, 7, Some(&dir)).unwrap();
        assert_eq!(d1.a, d2.a);
        assert_eq!(d1.y, d2.y);
        // different seed differs
        let d3 = load_dataset("bibtex", 0.05, 8, Some(&dir)).unwrap();
        assert_ne!(d1.a, d3.a);
    }

    #[test]
    fn stats_shape() {
        let dir = std::env::temp_dir().join("fastpi_ds_stats_test");
        let d = load_dataset("rcv", 0.02, 1, Some(&dir)).unwrap();
        let (m, n, l, nnz, spa, spy) = d.stats();
        assert_eq!(m, 1248);
        assert_eq!(n, 95);
        assert!(l >= 8);
        assert!(nnz > 0);
        assert!(spa > 0.5 && spa < 1.0);
        assert!(spy > 0.5 && spy < 1.0);
    }
}
