//! Synthetic multi-label dataset generator.
//!
//! Feature matrix: bipartite Chung–Lu-style sampling. Instance and feature
//! nodes draw weights from bounded discrete power laws; `nnz` edges are
//! sampled proportionally to weight products (deduplicated), yielding the
//! skewed degree distributions of Figure 1.
//!
//! Label matrix: a sparse ground-truth weight matrix W (n×L) assigns each
//! label a few characteristic features; an instance receives the top-t
//! labels by overlap score `(A·W)_i` plus noise. Labels are therefore a
//! (noisy) linear function of features — exactly the regime where
//! pseudoinverse regression (Application 1) is meaningful.

use crate::sparse::{Coo, Csr};
use crate::util::rng::Rng;
use std::collections::{HashMap, HashSet};

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    pub m: usize,
    pub n: usize,
    pub labels: usize,
    /// target number of non-zeros in A (approximate: deduplication may
    /// undershoot on dense configurations)
    pub nnz: usize,
    /// power-law exponent for instance-side weights (≈2 in real data)
    pub gamma_inst: f64,
    /// power-law exponent for feature-side weights
    pub gamma_feat: f64,
    /// characteristic features per label in the ground truth W
    pub feats_per_label: usize,
    /// maximum positive labels per instance
    pub max_labels_per_inst: usize,
    /// probability of replacing a true label with a random one (noise)
    pub label_noise: f64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            m: 1000,
            n: 300,
            labels: 100,
            nnz: 5000,
            gamma_inst: 2.0,
            gamma_feat: 2.0,
            feats_per_label: 4,
            max_labels_per_inst: 4,
            label_noise: 0.05,
        }
    }
}

/// Generate (feature matrix A, label matrix Y).
pub fn generate(cfg: &SynthConfig, rng: &mut Rng) -> (Csr, Csr) {
    let a = gen_features(cfg, rng);
    let y = gen_labels(cfg, &a, rng);
    (a, y)
}

fn cumsum(w: &[f64]) -> Vec<f64> {
    let mut c = Vec::with_capacity(w.len());
    let mut s = 0.0;
    for &x in w {
        s += x;
        c.push(s);
    }
    c
}

/// Weighted bipartite edge sampling with dedup.
fn gen_features(cfg: &SynthConfig, rng: &mut Rng) -> Csr {
    let wi: Vec<f64> = (0..cfg.m).map(|_| rng.power_law(cfg.gamma_inst, cfg.m as f64)).collect();
    let wf: Vec<f64> = (0..cfg.n).map(|_| rng.power_law(cfg.gamma_feat, cfg.n as f64)).collect();
    let (ci, cf) = (cumsum(&wi), cumsum(&wf));

    let target = cfg.nnz.min(cfg.m * cfg.n);
    let mut seen: HashSet<(u32, u32)> = HashSet::with_capacity(target * 2);
    let mut coo = Coo::with_capacity(cfg.m, cfg.n, target);
    let max_attempts = 20 * target + 1000;
    let mut attempts = 0usize;
    while coo.nnz() < target && attempts < max_attempts {
        attempts += 1;
        let i = rng.sample_cumulative(&ci) as u32;
        let j = rng.sample_cumulative(&cf) as u32;
        if seen.insert((i, j)) {
            // tf-idf-flavoured positive value; avoids exact-rank degeneracies
            coo.push(i as usize, j as usize, 0.5 + rng.f64());
        }
    }
    Csr::from_coo(&coo)
}

/// Ground-truth-linear label assignment.
fn gen_labels(cfg: &SynthConfig, a: &Csr, rng: &mut Rng) -> Csr {
    let l = cfg.labels;
    // label popularity weights (skewed, like real tag distributions)
    let wl: Vec<f64> = (0..l).map(|_| rng.power_law(2.0, l as f64)).collect();
    let cl = cumsum(&wl);

    // W: each label ℓ marks `feats_per_label` characteristic features,
    // weighted by feature popularity so hub features span many labels.
    let mut feat_to_labels: Vec<Vec<(usize, f64)>> = vec![Vec::new(); cfg.n];
    let wf: Vec<f64> = (0..cfg.n).map(|_| rng.power_law(cfg.gamma_feat, cfg.n as f64)).collect();
    let cf = cumsum(&wf);
    for label in 0..l {
        for _ in 0..cfg.feats_per_label {
            let j = rng.sample_cumulative(&cf);
            feat_to_labels[j].push((label, 0.5 + rng.f64()));
        }
    }

    let mut coo = Coo::new(a.rows(), l);
    let mut acc: HashMap<usize, f64> = HashMap::new();
    for i in 0..a.rows() {
        acc.clear();
        let (js, vs) = a.row(i);
        for (&j, &v) in js.iter().zip(vs) {
            for &(label, w) in &feat_to_labels[j] {
                *acc.entry(label).or_insert(0.0) += v * w;
            }
        }
        let t = rng.usize_range(1, cfg.max_labels_per_inst + 1);
        let mut scored: Vec<(usize, f64)> = acc.iter().map(|(&k, &v)| (k, v)).collect();
        rank_labels_desc(&mut scored);
        let mut assigned: HashSet<usize> = HashSet::new();
        for &(label, _) in scored.iter().take(t) {
            let final_label = if rng.f64() < cfg.label_noise {
                rng.sample_cumulative(&cl) // noise: popular random label
            } else {
                label
            };
            assigned.insert(final_label);
        }
        // cold start: instances with no feature overlap get one popular label
        if assigned.is_empty() && rng.f64() < 0.5 {
            assigned.insert(rng.sample_cumulative(&cl));
        }
        for label in assigned {
            coo.push(i, label, 1.0);
        }
    }
    Csr::from_coo(&coo)
}

/// Rank `(label, score)` pairs best-score-first, ties broken by label id.
/// `total_cmp` so a NaN score (a poisoned feature weight propagating
/// through the accumulator) still orders deterministically instead of
/// panicking the generator.
fn rank_labels_desc(scored: &mut [(usize, f64)]) {
    scored.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DegreeStats;

    #[test]
    fn shapes_and_nnz_near_target() {
        let cfg = SynthConfig { m: 500, n: 200, labels: 50, nnz: 3000, ..Default::default() };
        let mut rng = Rng::seed_from_u64(1);
        let (a, y) = generate(&cfg, &mut rng);
        assert_eq!(a.shape(), (500, 200));
        assert_eq!(y.shape(), (500, 50));
        assert!(a.nnz() >= 2700 && a.nnz() <= 3000, "nnz {}", a.nnz());
        assert!(y.nnz() > 0);
    }

    #[test]
    fn degrees_are_skewed() {
        let cfg = SynthConfig { m: 2000, n: 500, labels: 50, nnz: 12000, ..Default::default() };
        let mut rng = Rng::seed_from_u64(2);
        let (a, _) = generate(&cfg, &mut rng);
        let col_stats = DegreeStats::from_degrees(&a.col_degrees());
        // skew: Gini well above uniform and hubs carrying a large edge share
        assert!(col_stats.gini > 0.3, "col gini {}", col_stats.gini);
        assert!(col_stats.top1pct_edge_share > 0.05, "top1% {}", col_stats.top1pct_edge_share);
        assert!(col_stats.max > 10 * col_stats.median.max(1), "max {} median {}", col_stats.max, col_stats.median);
    }

    #[test]
    fn label_ranking_survives_nan_scores() {
        // regression: partial_cmp().unwrap() panicked on a NaN score
        let mut scored = vec![(3, 1.0), (1, f64::NAN), (2, 2.0), (0, 1.0)];
        rank_labels_desc(&mut scored);
        let labels: Vec<usize> = scored.iter().map(|&(l, _)| l).collect();
        // NaN is the maximum of the IEEE total order, so it ranks first;
        // the finite tail stays score-descending with id tiebreaks
        assert_eq!(labels, vec![1, 2, 0, 3]);
    }

    #[test]
    fn labels_sparse_and_bounded() {
        let cfg = SynthConfig { m: 800, n: 300, labels: 120, nnz: 6000, ..Default::default() };
        let mut rng = Rng::seed_from_u64(3);
        let (_, y) = generate(&cfg, &mut rng);
        assert!(y.sparsity() > 0.9, "sp(Y) = {}", y.sparsity());
        for i in 0..y.rows() {
            assert!(y.row_nnz(i) <= cfg.max_labels_per_inst, "row {i}");
        }
    }

    #[test]
    fn labels_are_learnable_signal() {
        // Labels must correlate with features: an instance sharing a label's
        // characteristic features should usually carry the label. We test
        // this indirectly: the dense least-squares fit on the TRAIN split
        // predicts held-out labels far better than chance.
        use crate::dense::svd;
        use crate::pinv::Pinv;
        use crate::regress::{precision_at_k, train_test_split, MultiLabelModel};
        let cfg = SynthConfig {
            m: 400,
            n: 80,
            labels: 30,
            nnz: 4000,
            label_noise: 0.02,
            ..Default::default()
        };
        let mut rng = Rng::seed_from_u64(4);
        let (a, y) = generate(&cfg, &mut rng);
        let split = train_test_split(&a, &y, 0.15, &mut rng);
        let p = Pinv::from_svd(&svd(&split.a_train.to_dense()));
        let (model, _) = MultiLabelModel::train(&p, &split.y_train);
        let scores = model.predict(&split.a_test);
        let p1 = precision_at_k(&scores, &split.y_test, 1);
        // chance level ≈ avg positives / labels ≈ 2.5/30 ≈ 0.08
        assert!(p1 > 0.25, "P@1 = {p1} — labels not learnable");
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = SynthConfig::default();
        let (a1, y1) = generate(&cfg, &mut Rng::seed_from_u64(9));
        let (a2, y2) = generate(&cfg, &mut Rng::seed_from_u64(9));
        assert_eq!(a1, a2);
        assert_eq!(y1, y2);
    }
}
