//! Named metric registry + the `METRICS` text rendering and merge rules.
//!
//! A [`Registry`] is a get-or-create map from a full series name (labels
//! inline, e.g. `fastpi_stage_ns{stage="gemm"}`) to a metric handle. Each
//! server owns its own registry — in-process fleets (tests, benches) must
//! not share buckets — while [`Registry::global`] offers one process-wide
//! instance for process-scoped metrics.
//!
//! `render` emits Prometheus-style text lines, one `name{labels} value`
//! per line, deterministically sorted by family name:
//!
//! * counters/gauges: `name value` (counters are monotone by contract);
//! * histograms: cumulative `<base>_bucket{...,le="<edge>"}` lines over
//!   the fixed edges of [`super::hist`] (empty buckets skipped, `+Inf`
//!   always present), then `<base>_count` and `<base>_sum`;
//! * Welford timing buckets: per batch size, mergeable integers
//!   `<base>_count{batch="b"}` / `<base>_total_ns{batch="b"}` plus float
//!   `<base>_mean_ns` / `<base>_var_ns2` estimates.
//!
//! **Merge rules** ([`merge_bodies`], used by the router's `METRICS`):
//! histogram buckets are parsed back into per-bucket counts (cumulative
//! differences over numerically sorted edges — members may emit different
//! non-empty subsets) and added exactly; integer families ending in
//! `_total`, `_count`, `_sum`, or `_total_ns` are summed by series name;
//! float series (means, variances, gauges) are dropped — means do not
//! add. Everything is u64 arithmetic, so the merged count is bitwise the
//! sum of the member counts. Label values must not contain commas.

use super::hist::{bucket_index, bucket_upper, HistSnapshot, Histogram, BUCKETS};
use super::welford::BatchTiming;
use super::{Counter, Gauge};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

#[derive(Default)]
struct Inner {
    counters: Vec<(String, Arc<Counter>)>,
    gauges: Vec<(String, Arc<Gauge>)>,
    hists: Vec<(String, Arc<Histogram>)>,
    timings: Vec<(String, Arc<BatchTiming>)>,
}

/// Process- or server-scoped collection of named metrics.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

fn get_or_insert<T>(list: &mut Vec<(String, Arc<T>)>, name: &str, make: impl FnOnce() -> T) -> Arc<T> {
    if let Some((_, v)) = list.iter().find(|(n, _)| n == name) {
        return Arc::clone(v);
    }
    let v = Arc::new(make());
    list.push((name.to_string(), Arc::clone(&v)));
    v
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// The process-wide registry.
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::new)
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        get_or_insert(&mut inner.counters, name, Counter::new)
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        get_or_insert(&mut inner.gauges, name, Gauge::new)
    }

    pub fn hist(&self, name: &str) -> Arc<Histogram> {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        get_or_insert(&mut inner.hists, name, Histogram::new)
    }

    pub fn timing(&self, name: &str) -> Arc<BatchTiming> {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        get_or_insert(&mut inner.timings, name, BatchTiming::new)
    }

    /// Render every registered metric as sorted Prometheus-style lines.
    pub fn render(&self) -> String {
        // clone the handle lists out and drop the guard before touching
        // any metric's own lock (BatchTiming) — keeps the lock graph flat
        let (counters, gauges, hists, timings) = {
            let inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
            (
                inner.counters.clone(),
                inner.gauges.clone(),
                inner.hists.clone(),
                inner.timings.clone(),
            )
        };
        let mut blocks: BTreeMap<String, String> = BTreeMap::new();
        for (name, c) in counters {
            blocks.insert(name.clone(), format!("{name} {}\n", c.get()));
        }
        for (name, g) in gauges {
            blocks.insert(name.clone(), format!("{name} {}\n", g.get()));
        }
        for (name, h) in hists {
            let snap = h.snapshot();
            blocks.insert(name.clone(), render_hist(&name, &snap));
        }
        for (name, t) in timings {
            let mut out = String::new();
            for st in t.stats() {
                let b = st.batch;
                out.push_str(&format!("{name}_count{{batch=\"{b}\"}} {}\n", st.count));
                out.push_str(&format!("{name}_total_ns{{batch=\"{b}\"}} {}\n", st.total_ns));
                out.push_str(&format!("{name}_mean_ns{{batch=\"{b}\"}} {:?}\n", st.mean_ns));
                out.push_str(&format!("{name}_var_ns2{{batch=\"{b}\"}} {:?}\n", st.var_ns2));
            }
            blocks.insert(name, out);
        }
        blocks.into_values().collect()
    }
}

/// Split a full series name into (base, labels-without-braces).
fn split_labels(name: &str) -> (&str, &str) {
    match name.find('{') {
        Some(i) => (&name[..i], name[i + 1..].trim_end_matches('}')),
        None => (name, ""),
    }
}

/// Compose `base_suffix{labels,extra}` with correct brace handling.
fn series(base: &str, suffix: &str, labels: &str, extra: &str) -> String {
    let mut l = String::new();
    if !labels.is_empty() {
        l.push_str(labels);
    }
    if !extra.is_empty() {
        if !l.is_empty() {
            l.push(',');
        }
        l.push_str(extra);
    }
    if l.is_empty() {
        format!("{base}{suffix}")
    } else {
        format!("{base}{suffix}{{{l}}}")
    }
}

/// Render one histogram family: cumulative non-empty buckets, `+Inf`,
/// count, sum.
pub fn render_hist(name: &str, snap: &HistSnapshot) -> String {
    let (base, labels) = split_labels(name);
    let mut out = String::new();
    let mut cum = 0u64;
    for (i, &b) in snap.buckets.iter().enumerate() {
        if b == 0 {
            continue;
        }
        cum += b;
        let edge = bucket_upper(i);
        out.push_str(&series(base, "_bucket", labels, &format!("le=\"{edge}\"")));
        out.push_str(&format!(" {cum}\n"));
    }
    out.push_str(&series(base, "_bucket", labels, "le=\"+Inf\""));
    out.push_str(&format!(" {cum}\n"));
    out.push_str(&series(base, "_count", labels, ""));
    out.push_str(&format!(" {cum}\n"));
    out.push_str(&series(base, "_sum", labels, ""));
    out.push_str(&format!(" {}\n", snap.sum));
    out
}

/// Parse every `name value` line of a METRICS body into (series, value)
/// pairs; non-numeric or malformed lines are reported as errors. Used by
/// the CI checks to assert the surface parses and counters are monotone.
pub fn parse_scalars(body: &str) -> Result<Vec<(String, f64)>, String> {
    let mut out = Vec::new();
    for line in body.lines() {
        if line.is_empty() {
            continue;
        }
        let Some((name, value)) = line.rsplit_once(' ') else {
            return Err(format!("unparseable metrics line `{line}`"));
        };
        if name.is_empty() || name.starts_with(|c: char| !c.is_ascii_alphabetic()) {
            return Err(format!("bad series name in `{line}`"));
        }
        let v: f64 = value
            .parse()
            .map_err(|_| format!("non-numeric value in `{line}`"))?;
        out.push((name.to_string(), v));
    }
    Ok(out)
}

/// Does this base family name merge by integer summation?
fn summable(base: &str) -> bool {
    base.ends_with("_total")
        || base.ends_with("_count")
        || base.ends_with("_sum")
        || base.ends_with("_total_ns")
}

/// Remove the `le="..."` label from a label list, returning (rest, edge).
fn take_le(labels: &str) -> Option<(String, &str)> {
    let mut rest = Vec::new();
    let mut edge = None;
    for part in labels.split(',') {
        match part.strip_prefix("le=\"").and_then(|p| p.strip_suffix('"')) {
            Some(e) => edge = Some(e),
            None => rest.push(part),
        }
    }
    edge.map(|e| (rest.join(","), e))
}

/// Merge METRICS bodies per the module-doc rules. Histograms are
/// reconstructed bucket-exact; integer families are summed by series
/// name; float series are dropped.
pub fn merge_bodies(bodies: &[String]) -> String {
    // full hist name -> (bucket counts, sum)
    let mut hists: BTreeMap<String, HistSnapshot> = BTreeMap::new();
    let mut scalars: BTreeMap<String, u64> = BTreeMap::new();
    for body in bodies {
        // per-body cumulative bucket lists, diffed once the body is read
        let mut cums: BTreeMap<String, Vec<(u64, u64)>> = BTreeMap::new();
        for line in body.lines() {
            let Some((name, value)) = line.rsplit_once(' ') else { continue };
            let (family, labels) = split_labels(name);
            if let Some(base) = family.strip_suffix("_bucket") {
                let Some((rest, edge)) = take_le(labels) else { continue };
                if edge == "+Inf" {
                    continue;
                }
                let (Ok(edge), Ok(cum)) = (edge.parse::<u64>(), value.parse::<u64>()) else {
                    continue;
                };
                let key = if rest.is_empty() {
                    base.to_string()
                } else {
                    format!("{base}{{{rest}}}")
                };
                cums.entry(key).or_default().push((edge, cum));
            } else if summable(family) {
                if let Ok(v) = value.parse::<u64>() {
                    *scalars.entry(name.to_string()).or_insert(0) += v;
                }
            }
        }
        for (key, mut edges) in cums {
            edges.sort_unstable();
            let snap = hists.entry(key).or_insert_with(HistSnapshot::empty);
            let mut prev = 0u64;
            for (edge, cum) in edges {
                let idx = bucket_index(edge).min(BUCKETS - 1);
                snap.buckets[idx] += cum.saturating_sub(prev);
                prev = cum;
            }
        }
    }
    // hist count/sum lines were summed into `scalars`; fold the sums back
    // into the snapshots and drop the owned series from the scalar render
    let mut blocks: BTreeMap<String, String> = BTreeMap::new();
    for (key, snap) in &mut hists {
        let (base, labels) = split_labels(key);
        scalars.remove(&series(base, "_count", labels, ""));
        if let Some(sum) = scalars.remove(&series(base, "_sum", labels, "")) {
            snap.sum = sum;
        }
        blocks.insert(key.clone(), render_hist(key, snap));
    }
    for (name, v) in scalars {
        blocks.insert(name.clone(), format!("{name} {v}\n"));
    }
    blocks.into_values().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_get_or_create_and_render() {
        let r = Registry::new();
        let c = r.counter("fastpi_test_total");
        c.inc();
        c.add(2);
        assert_eq!(r.counter("fastpi_test_total").get(), 3);
        let g = r.gauge("fastpi_depth");
        g.set(7);
        let h = r.hist("fastpi_lat_ns{stage=\"gemm\"}");
        h.record(100);
        h.record(5000);
        let t = r.timing("fastpi_batch");
        t.record(8, 1000);
        let body = r.render();
        assert!(body.contains("fastpi_test_total 3\n"));
        assert!(body.contains("fastpi_depth 7\n"));
        assert!(body.contains("fastpi_lat_ns_count{stage=\"gemm\"} 2\n"));
        assert!(body.contains("fastpi_lat_ns_sum{stage=\"gemm\"} 5100\n"));
        assert!(body.contains("le=\"+Inf\"} 2\n"));
        assert!(body.contains("fastpi_batch_count{batch=\"8\"} 1\n"));
        assert!(body.contains("fastpi_batch_total_ns{batch=\"8\"} 1000\n"));
        assert!(body.contains("fastpi_batch_mean_ns{batch=\"8\"} 1000.0\n"));
        // every line parses, values numeric
        let scalars = parse_scalars(&body).expect("body parses");
        assert!(scalars.len() >= 8);
    }

    #[test]
    fn render_then_merge_reconstructs_buckets_exactly() {
        // two members with different bucket subsets merge to exactly the
        // union histogram — count == sum of member counts, bucket-exact
        let a = Histogram::new();
        for v in [3u64, 3, 900, 1 << 20] {
            a.record(v);
        }
        let b = Histogram::new();
        for v in [70u64, 70, 70, 1 << 40] {
            b.record(v);
        }
        let body_a = render_hist("fastpi_x_ns", &a.snapshot());
        let body_b = render_hist("fastpi_x_ns", &b.snapshot());
        let merged = merge_bodies(&[body_a, body_b]);
        let mut want = a.snapshot();
        want.merge(&b.snapshot());
        assert_eq!(merged, render_hist("fastpi_x_ns", &want));
        assert!(merged.contains("fastpi_x_ns_count 8\n"));
    }

    #[test]
    fn merge_sums_integers_and_drops_floats() {
        let a = "fastpi_served_total 5\nfastpi_mean_ns 12.5\n".to_string();
        let b = "fastpi_served_total 7\nfastpi_mean_ns 90.5\n".to_string();
        let merged = merge_bodies(&[a, b]);
        assert_eq!(merged, "fastpi_served_total 12\n");
    }

    #[test]
    fn merge_is_order_insensitive_on_line_order() {
        let fwd = "fastpi_y_ns_bucket{le=\"15\"} 2\nfastpi_y_ns_bucket{le=\"95\"} 5\nfastpi_y_ns_bucket{le=\"+Inf\"} 5\nfastpi_y_ns_count 5\nfastpi_y_ns_sum 300\n";
        let rev: String = fwd.lines().rev().map(|l| format!("{l}\n")).collect();
        assert_eq!(
            merge_bodies(&[fwd.to_string()]),
            merge_bodies(&[rev]),
            "cumulative parse must sort edges numerically"
        );
    }
}
