//! Per-batch-size Welford timing buckets (the cervo `timing.rs` design).
//!
//! One slot per observed batch size accumulates count / mean / M2 with
//! Welford's online algorithm, so the batcher (and, later, deadline-aware
//! batching per ROADMAP item 1) can ask "what does a batch of size b cost,
//! and how noisy is that estimate?" without storing samples. Slots live in
//! a `BTreeMap` behind one leaf mutex — recording happens once per batch,
//! not per request, so a lock is cheap and keeps mean/M2 updates atomic as
//! a pair; iteration order is deterministic for rendering.
//!
//! Rendering emits, per batch size, the integer mergeable pair
//! (`count`, `total_ns`) alongside the float `mean_ns` / `var_ns2`
//! estimates; mergers (the router) keep the integers and drop the floats —
//! means do not add.

use std::collections::BTreeMap;
use std::sync::Mutex;

#[derive(Clone, Copy, Default)]
struct Slot {
    count: u64,
    mean: f64,
    m2: f64,
    total_ns: u64,
}

/// One batch size's accumulated timing statistics.
#[derive(Clone, Copy, Debug)]
pub struct BatchStat {
    pub batch: usize,
    pub count: u64,
    pub mean_ns: f64,
    /// Population variance (M2 / count), 0 for a single observation.
    pub var_ns2: f64,
    pub total_ns: u64,
}

/// Per-batch-size Welford mean/variance buckets.
#[derive(Default)]
pub struct BatchTiming {
    slots: Mutex<BTreeMap<usize, Slot>>,
}

impl BatchTiming {
    pub fn new() -> BatchTiming {
        BatchTiming::default()
    }

    /// Fold one observation (a batch of `batch` items took `ns`
    /// nanoseconds) into that batch size's slot.
    pub fn record(&self, batch: usize, ns: u64) {
        let mut slots = self.slots.lock().unwrap_or_else(|p| p.into_inner());
        let s = slots.entry(batch).or_default();
        s.count += 1;
        s.total_ns = s.total_ns.saturating_add(ns);
        let x = ns as f64;
        let delta = x - s.mean;
        s.mean += delta / s.count as f64;
        s.m2 += delta * (x - s.mean);
    }

    /// Mean cost estimate for a batch size, if it has been observed.
    pub fn mean_ns(&self, batch: usize) -> Option<f64> {
        let slots = self.slots.lock().unwrap_or_else(|p| p.into_inner());
        slots.get(&batch).filter(|s| s.count > 0).map(|s| s.mean)
    }

    /// All observed batch sizes' stats, ascending by batch size.
    pub fn stats(&self) -> Vec<BatchStat> {
        let slots = self.slots.lock().unwrap_or_else(|p| p.into_inner());
        slots
            .iter()
            .map(|(&batch, s)| BatchStat {
                batch,
                count: s.count,
                mean_ns: s.mean,
                var_ns2: if s.count > 0 { s.m2 / s.count as f64 } else { 0.0 },
                total_ns: s.total_ns,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Welford must agree with the naive two-pass mean/variance.
    #[test]
    fn welford_matches_naive_mean_and_variance() {
        let mut rng = Rng::seed_from_u64(99);
        let t = BatchTiming::new();
        let mut by_batch: BTreeMap<usize, Vec<u64>> = BTreeMap::new();
        for _ in 0..5000 {
            let batch = 1usize << (rng.next_u64() % 7);
            let ns = 1000 + rng.next_u64() % 10_000_000;
            t.record(batch, ns);
            by_batch.entry(batch).or_default().push(ns);
        }
        for st in t.stats() {
            let xs = &by_batch[&st.batch];
            assert_eq!(st.count, xs.len() as u64);
            assert_eq!(st.total_ns, xs.iter().sum::<u64>());
            let naive_mean = xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64;
            let naive_var = xs
                .iter()
                .map(|&x| (x as f64 - naive_mean).powi(2))
                .sum::<f64>()
                / xs.len() as f64;
            let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1.0);
            assert!(
                rel(st.mean_ns, naive_mean) < 1e-9,
                "batch {}: welford mean {} vs naive {naive_mean}",
                st.batch,
                st.mean_ns
            );
            assert!(
                rel(st.var_ns2, naive_var) < 1e-6,
                "batch {}: welford var {} vs naive {naive_var}",
                st.batch,
                st.var_ns2
            );
        }
    }

    #[test]
    fn mean_lookup_and_empty_behavior() {
        let t = BatchTiming::new();
        assert!(t.mean_ns(8).is_none());
        assert!(t.stats().is_empty());
        t.record(8, 100);
        t.record(8, 300);
        let m = t.mean_ns(8).unwrap();
        assert!((m - 200.0).abs() < 1e-12);
        assert!(t.mean_ns(16).is_none());
        let st = &t.stats()[0];
        assert_eq!((st.batch, st.count, st.total_ns), (8, 2, 400));
        assert!((st.var_ns2 - 10_000.0).abs() < 1e-9);
    }
}
