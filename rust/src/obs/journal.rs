//! Fixed-capacity ring-buffer event journal.
//!
//! Lifecycle moments that flat counters can't reconstruct — hot swaps,
//! LEARN folds, promotions, circuit trips, snapshot ships, reshards — are
//! appended as [`Event`]s with a monotonic sequence number and a
//! monotonic timestamp (nanoseconds since the journal was created; wall
//! clocks never appear, so replays and tests stay deterministic enough to
//! assert ordering). Capacity is fixed at construction: when full, the
//! oldest entry is overwritten and `dropped` counts the loss, so the
//! journal is O(capacity) memory no matter how long the process lives.
//! The `EVENTS [n]` verb drains (removes) entries oldest-first.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

/// What happened. The wire spelling (`as_str`) is part of the `EVENTS`
/// surface documented in `coordinator/serve.rs`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    Swap,
    Learn,
    Promote,
    CircuitOpen,
    CircuitClose,
    Ship,
    Reshard,
}

impl EventKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            EventKind::Swap => "swap",
            EventKind::Learn => "learn",
            EventKind::Promote => "promote",
            EventKind::CircuitOpen => "circuit_open",
            EventKind::CircuitClose => "circuit_close",
            EventKind::Ship => "ship",
            EventKind::Reshard => "reshard",
        }
    }
}

/// One journal entry.
#[derive(Clone, Debug)]
pub struct Event {
    /// Monotonic per-journal sequence number, never reused; gaps after
    /// wraparound reveal how many events were overwritten.
    pub seq: u64,
    /// Nanoseconds since journal creation (monotonic clock).
    pub t_ns: u64,
    pub kind: EventKind,
    /// Free-form detail, e.g. `version=7`.
    pub detail: String,
}

struct Inner {
    buf: VecDeque<Event>,
    next_seq: u64,
    dropped: u64,
}

/// The ring. One leaf mutex; record is a push + possible pop-front.
pub struct Journal {
    cap: usize,
    t0: Instant,
    inner: Mutex<Inner>,
}

impl Journal {
    pub fn new(cap: usize) -> Journal {
        Journal {
            cap: cap.max(1),
            t0: Instant::now(),
            inner: Mutex::new(Inner {
                buf: VecDeque::new(),
                next_seq: 0,
                dropped: 0,
            }),
        }
    }

    pub fn record(&self, kind: EventKind, detail: impl Into<String>) {
        let t_ns = u64::try_from(self.t0.elapsed().as_nanos()).unwrap_or(u64::MAX);
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let seq = inner.next_seq;
        inner.next_seq += 1;
        if inner.buf.len() == self.cap {
            inner.buf.pop_front();
            inner.dropped += 1;
        }
        inner.buf.push_back(Event { seq, t_ns, kind, detail: detail.into() });
    }

    /// Remove and return up to `max` entries, oldest first (0 = all).
    pub fn drain(&self, max: usize) -> Vec<Event> {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let take = if max == 0 { inner.buf.len() } else { max.min(inner.buf.len()) };
        inner.buf.drain(..take).collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap_or_else(|p| p.into_inner()).buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events overwritten by wraparound before anyone drained them.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap_or_else(|p| p.into_inner()).dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wraparound_keeps_newest_and_counts_drops() {
        let j = Journal::new(4);
        for i in 0..10 {
            j.record(EventKind::Swap, format!("version={i}"));
        }
        assert_eq!(j.len(), 4);
        assert_eq!(j.dropped(), 6);
        let events = j.drain(0);
        assert_eq!(events.len(), 4);
        // the survivors are the newest four, in order, with original seqs
        let seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
        assert_eq!(events[0].detail, "version=6");
        assert!(j.is_empty());
        // timestamps are monotone non-decreasing
        for w in events.windows(2) {
            assert!(w[0].t_ns <= w[1].t_ns);
        }
    }

    #[test]
    fn drain_is_bounded_and_oldest_first() {
        let j = Journal::new(8);
        j.record(EventKind::Learn, "version=1");
        j.record(EventKind::Ship, "version=1");
        j.record(EventKind::Promote, "epoch=1");
        let first = j.drain(2);
        assert_eq!(first.len(), 2);
        assert_eq!(first[0].kind, EventKind::Learn);
        assert_eq!(first[1].kind, EventKind::Ship);
        let rest = j.drain(0);
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].kind, EventKind::Promote);
        assert_eq!(rest[0].kind.as_str(), "promote");
        assert_eq!(j.dropped(), 0);
    }
}
