//! Zero-dependency observability substrate for the serving tier.
//!
//! Everything in here is **observation only**: recording never branches
//! the math, never allocates on the reply path's byte formatting, and
//! never appears in a reply — SCORE/LEARN bytes are bitwise identical
//! with instrumentation on or off (asserted by
//! `coordinator::serve::tests::score_bytes_identical_with_obs_on_and_off`).
//! The numeric kernels stay clock-free; only this layer and the serving
//! files read monotonic clocks.
//!
//! Pieces (see `rust/src/obs/README.md` for the metric catalogue):
//!
//! * [`Counter`] / [`Gauge`] — lock-free relaxed `AtomicU64`s;
//! * [`Histogram`] — log2-bucketed (4 linear sub-buckets per octave)
//!   mergeable latency histogram with p50/p95/p99/p999 reads and no
//!   sample storage;
//! * [`BatchTiming`] — per-batch-size Welford mean/variance buckets (the
//!   cervo `timing.rs` design), the feed for deadline-aware batching;
//! * [`Registry`] — named-metric registry rendering the Prometheus-style
//!   `METRICS` body, plus [`registry::merge_bodies`] for the router's
//!   merged view;
//! * [`Journal`] — fixed-capacity ring-buffer event journal behind the
//!   `EVENTS` verb.

pub mod hist;
pub mod journal;
pub mod registry;
pub mod welford;

pub use hist::{HistSnapshot, Histogram};
pub use journal::{Event, EventKind, Journal};
pub use registry::Registry;
pub use welford::{BatchStat, BatchTiming};

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotone lock-free counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Lock-free last-value gauge.
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
        let g = Gauge::new();
        assert_eq!(g.get(), 0);
        g.set(9);
        g.set(3);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn counters_are_monotone_across_threads() {
        let c = Counter::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 4000);
    }
}
