//! Log2-bucketed mergeable latency histogram.
//!
//! Values (nanoseconds by convention) land in one of [`BUCKETS`] fixed
//! buckets: values below 16 get exact unit buckets, everything above is
//! bucketed by octave (log2) with 4 linear sub-buckets per octave — the
//! HDR idiom — so the bucket upper edge over-reports a recorded value by
//! at most 25%. The fixed, global bucket edges are the point: two
//! histograms (from two servers, or two phases) merge by per-bucket
//! addition with no resampling, and the merged count is exactly the sum
//! of the member counts. Buckets are relaxed `AtomicU64`s, so recording
//! is lock-free and wait-free; percentile reads (p50/p95/p99/p999) walk
//! a self-consistent snapshot and need no stored samples.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of fixed buckets (16 unit buckets + 60 octaves × 4 sub-buckets).
pub const BUCKETS: usize = 256;

/// Bucket index for a value: exact below 16, then octave × 4 linear
/// sub-buckets.
pub fn bucket_index(v: u64) -> usize {
    if v < 16 {
        return v as usize;
    }
    let k = 63 - v.leading_zeros() as usize; // 2^k <= v < 2^(k+1), k >= 4
    let sub = ((v >> (k - 2)) & 3) as usize;
    16 + (k - 4) * 4 + sub
}

/// Largest value that lands in bucket `i` (the `le=` edge it renders as).
pub fn bucket_upper(i: usize) -> u64 {
    if i < 16 {
        return i as u64;
    }
    let k = 4 + (i - 16) / 4;
    let sub = ((i - 16) % 4) as u64;
    if k >= 63 && sub == 3 {
        return u64::MAX;
    }
    (1u64 << k) + (sub + 1) * (1u64 << (k - 2)) - 1
}

/// A point-in-time copy of a histogram: per-bucket counts plus the value
/// sum. All percentile math runs on snapshots so one read is internally
/// consistent; this is also the unit the router merges after parsing a
/// member's `METRICS` body back into bucket counts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: Vec<u64>,
    pub sum: u64,
}

impl HistSnapshot {
    pub fn empty() -> HistSnapshot {
        HistSnapshot { buckets: vec![0; BUCKETS], sum: 0 }
    }

    /// Total recorded values (derived from the buckets, not a separate
    /// counter, so it always agrees with percentile walks).
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Merge another snapshot in: per-bucket addition. Associative and
    /// commutative by construction.
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.sum += other.sum;
    }

    /// Quantile estimate: the upper edge of the bucket holding the
    /// ceil(q·count)-th smallest value. Guaranteed ≥ the true sample
    /// quantile and ≤ 1.25× it (one sub-bucket of slack).
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= rank {
                return bucket_upper(i);
            }
        }
        bucket_upper(BUCKETS - 1)
    }
}

/// The live, lock-free histogram. `record` is safe from any thread;
/// `snapshot` gives readers a consistent view.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
        }
    }

    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record a duration in nanoseconds (saturating at u64::MAX).
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }

    pub fn quantile(&self, q: f64) -> u64 {
        self.snapshot().quantile(q)
    }

    /// Merge a snapshot (e.g. a parsed wire histogram) into this one.
    pub fn merge_snapshot(&self, other: &HistSnapshot) {
        for (a, b) in self.buckets.iter().zip(&other.buckets) {
            a.fetch_add(*b, Ordering::Relaxed);
        }
        self.sum.fetch_add(other.sum, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_edges_are_consistent_and_increasing() {
        // every value's bucket upper edge is >= the value and < 1.25x it
        let mut rng = Rng::seed_from_u64(7);
        let mut probe = |v: u64| {
            let i = bucket_index(v);
            let hi = bucket_upper(i);
            assert!(hi >= v, "edge {hi} below value {v}");
            assert!(hi - v <= v / 4, "edge {hi} over-reports {v} by more than 25%");
            // the edge itself maps back to the same bucket
            assert_eq!(bucket_index(hi), i, "edge {hi} not in its own bucket");
        };
        for v in 0..4096u64 {
            probe(v);
        }
        for _ in 0..10_000 {
            let shift = (rng.next_u64() % 63) as u32;
            probe(rng.next_u64() >> shift);
        }
        probe(u64::MAX);
        // edges strictly increase, so cumulative rendering is monotone
        for i in 1..BUCKETS {
            assert!(bucket_upper(i) > bucket_upper(i - 1));
        }
    }

    fn fill(samples: &[u64]) -> Histogram {
        let h = Histogram::new();
        for &s in samples {
            h.record(s);
        }
        h
    }

    fn random_samples(seed: u64, n: usize) -> Vec<u64> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n).map(|_| rng.next_u64() >> (32 + (rng.next_u64() % 28) as u32)).collect()
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let (a, b, c) =
            (random_samples(1, 500), random_samples(2, 300), random_samples(3, 700));
        let (ha, hb, hc) = (fill(&a), fill(&b), fill(&c));
        // (a ∪ b) ∪ c == a ∪ (b ∪ c)
        let mut ab_c = ha.snapshot();
        ab_c.merge(&hb.snapshot());
        ab_c.merge(&hc.snapshot());
        let mut bc = hb.snapshot();
        bc.merge(&hc.snapshot());
        let mut a_bc = ha.snapshot();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc);
        // a ∪ b == b ∪ a
        let mut ab = ha.snapshot();
        ab.merge(&hb.snapshot());
        let mut ba = hb.snapshot();
        ba.merge(&ha.snapshot());
        assert_eq!(ab, ba);
        // merged count is exactly the sum of member counts
        assert_eq!(ab_c.count(), (a.len() + b.len() + c.len()) as u64);
        // and identical to recording everything into one histogram
        let mut all = a.clone();
        all.extend(&b);
        all.extend(&c);
        assert_eq!(ab_c, fill(&all).snapshot());
    }

    #[test]
    fn cumulative_buckets_are_monotone() {
        let h = fill(&random_samples(4, 2000));
        let snap = h.snapshot();
        let mut cum = 0u64;
        let mut prev = 0u64;
        for b in &snap.buckets {
            cum += b;
            assert!(cum >= prev);
            prev = cum;
        }
        assert_eq!(cum, snap.count());
    }

    #[test]
    fn quantiles_bound_the_exact_sorted_percentile() {
        for seed in 0..8u64 {
            let samples = random_samples(10 + seed, 1500);
            let h = fill(&samples);
            let mut sorted = samples.clone();
            sorted.sort_unstable();
            for q in [0.5, 0.95, 0.99, 0.999] {
                let rank = ((q * sorted.len() as f64).ceil() as usize)
                    .clamp(1, sorted.len());
                let exact = sorted[rank - 1];
                let est = h.quantile(q);
                assert!(est >= exact, "q{q}: est {est} < exact {exact}");
                assert!(
                    est <= exact + exact / 4,
                    "q{q}: est {est} > 1.25x exact {exact}"
                );
            }
        }
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.99), 0);
    }
}
