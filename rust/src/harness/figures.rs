//! Figure 1 (degree distributions) and Figure 3 (reordering progress +
//! block structure) harnesses, with text renderings (log-binned series and
//! an ASCII spy plot).

use crate::data::load_dataset;
use crate::error::Result;
use crate::graph::{log_binned_histogram, DegreeStats};
use crate::reorder::{reorder, ReorderConfig, Reordering};
use crate::sparse::Csr;

/// Figure 1: degree distribution evidence for one dataset.
#[derive(Debug)]
pub struct Fig1 {
    pub dataset: String,
    pub instance_stats: DegreeStats,
    pub feature_stats: DegreeStats,
    /// log-binned histograms: (lo, hi, count)
    pub instance_hist: Vec<(usize, usize, usize)>,
    pub feature_hist: Vec<(usize, usize, usize)>,
}

pub fn fig1(dataset: &str, scale: f64, seed: u64) -> Result<Fig1> {
    let ds = load_dataset(dataset, scale, seed, None)?;
    let rd = ds.a.row_degrees();
    let cd = ds.a.col_degrees();
    Ok(Fig1 {
        dataset: dataset.to_string(),
        instance_stats: DegreeStats::from_degrees(&rd),
        feature_stats: DegreeStats::from_degrees(&cd),
        instance_hist: log_binned_histogram(&rd),
        feature_hist: log_binned_histogram(&cd),
    })
}

pub fn render_fig1(f: &Fig1) -> String {
    let mut out = format!("== Figure 1: degree distributions — {} ==\n", f.dataset);
    let fmt_stats = |name: &str, s: &DegreeStats| {
        format!(
            "{name}: count={} max={} mean={:.2} median={} gini={:.3} top1%edges={:.2}\n",
            s.count, s.max, s.mean, s.median, s.gini, s.top1pct_edge_share
        )
    };
    out.push_str(&fmt_stats("instances", &f.instance_stats));
    out.push_str(&fmt_stats("features ", &f.feature_stats));
    for (name, hist) in [("instance", &f.instance_hist), ("feature", &f.feature_hist)] {
        out.push_str(&format!("{name} degree histogram (log-binned):\n"));
        for &(lo, hi, count) in hist {
            let bar = "#".repeat(((count as f64 + 1.0).log2() as usize).min(60));
            out.push_str(&format!("  [{lo:>6},{hi:>6}] {count:>7} {bar}\n"));
        }
    }
    out
}

/// Figure 3: reordering progress of one dataset.
#[derive(Debug)]
pub struct Fig3 {
    pub dataset: String,
    pub reordering: Reordering,
    /// nnz density of A11 / A12+A21 / A22 regions after reordering
    pub nnz_a11: usize,
    pub nnz_off: usize,
    pub nnz_a22: usize,
    pub spy: String,
}

pub fn fig3(dataset: &str, scale: f64, seed: u64) -> Result<Fig3> {
    let ds = load_dataset(dataset, scale, seed, None)?;
    let r = reorder(&ds.a, &ReorderConfig { k: ds.k, max_iters: 1000 });
    let b = r.apply(&ds.a);
    let (m1, n1, m2, n2) = (r.m1, r.n1, r.m2, r.n2);
    let nnz_a11 = b.nnz_in_region(0, 0, m1, n1);
    let nnz_a22 = b.nnz_in_region(m1, n1, m2, n2);
    let nnz_off = b.nnz() - nnz_a11 - nnz_a22;
    let spy = spy_plot(&b, 48, 24);
    Ok(Fig3 { dataset: dataset.to_string(), reordering: r, nnz_a11, nnz_off, nnz_a22, spy })
}

pub fn render_fig3(f: &Fig3) -> String {
    let r = &f.reordering;
    let mut out = format!(
        "== Figure 3: reordering — {} ==\nm1={} n1={} m2={} n2={} blocks={} iters={}\n",
        f.dataset,
        r.m1,
        r.n1,
        r.m2,
        r.n2,
        r.blocks.len(),
        r.iterations()
    );
    out.push_str("iter  m_hub n_hub  spokes(i/f)  comps   GCC(i/f)\n");
    for t in &r.trace {
        out.push_str(&format!(
            "{:>4} {:>6} {:>5} {:>6}/{:<6} {:>6} {:>7}/{:<7}\n",
            t.iter, t.m_hub, t.n_hub, t.spoke_insts, t.spoke_feats, t.num_spoke_comps,
            t.gcc_insts, t.gcc_feats
        ));
    }
    let total = (f.nnz_a11 + f.nnz_off + f.nnz_a22).max(1);
    out.push_str(&format!(
        "nnz split: A11 {} ({:.1}%)  off-diag {} ({:.1}%)  A22 {} ({:.1}%)\n",
        f.nnz_a11,
        100.0 * f.nnz_a11 as f64 / total as f64,
        f.nnz_off,
        100.0 * f.nnz_off as f64 / total as f64,
        f.nnz_a22,
        100.0 * f.nnz_a22 as f64 / total as f64
    ));
    out.push_str("spy plot (reordered; darker = denser):\n");
    out.push_str(&f.spy);
    out
}

/// ASCII density plot of a sparse matrix on a `w`×`h` character grid.
pub fn spy_plot(a: &Csr, w: usize, h: usize) -> String {
    let (m, n) = a.shape();
    if m == 0 || n == 0 {
        return String::from("(empty)\n");
    }
    let mut counts = vec![0usize; w * h];
    for i in 0..m {
        let gy = (i * h / m).min(h - 1);
        let (js, _) = a.row(i);
        for &j in js {
            let gx = (j * w / n).min(w - 1);
            counts[gy * w + gx] += 1;
        }
    }
    let max = counts.iter().copied().max().unwrap_or(0).max(1);
    let glyphs = [' ', '.', ':', '+', '*', '#', '@'];
    let mut out = String::with_capacity((w + 3) * h);
    for row in counts.chunks(w) {
        out.push('|');
        for &c in row {
            let level = if c == 0 {
                0
            } else {
                1 + ((c as f64).ln() / (max as f64).ln().max(1e-9) * (glyphs.len() - 2) as f64)
                    .round() as usize
            };
            out.push(glyphs[level.min(glyphs.len() - 1)]);
        }
        out.push_str("|\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_reports_skew() {
        let f = fig1("rcv", 0.03, 1).unwrap();
        assert!(f.feature_stats.gini > 0.2, "gini {}", f.feature_stats.gini);
        let total: usize = f.feature_hist.iter().map(|b| b.2).sum();
        assert_eq!(total, f.feature_stats.count);
        let text = render_fig1(&f);
        assert!(text.contains("degree histogram"));
    }

    #[test]
    fn fig3_concentrates_mass() {
        let f = fig3("rcv", 0.03, 1).unwrap();
        let total = f.nnz_a11 + f.nnz_off + f.nnz_a22;
        assert!(total > 0);
        // A22 occupies a small fraction of the area but a large nnz share
        let r = &f.reordering;
        let area_frac = (r.m2 * r.n2) as f64
            / ((r.m1 + r.m2) * (r.n1 + r.n2)) as f64;
        let nnz_frac = f.nnz_a22 as f64 / total as f64;
        assert!(
            nnz_frac > area_frac,
            "A22 nnz share {nnz_frac:.3} should exceed its area share {area_frac:.3}"
        );
        assert!(render_fig3(&f).contains("spy plot"));
    }

    #[test]
    fn spy_plot_dimensions() {
        let f = fig3("bibtex", 0.03, 2).unwrap();
        let lines: Vec<&str> = f.spy.lines().collect();
        assert_eq!(lines.len(), 24);
        assert!(lines.iter().all(|l| l.len() == 50)); // 48 + 2 borders
    }
}
