//! The core sweep runner behind Figures 4, 5, and 6: for each
//! (dataset × α × method) cell it computes the low-rank SVD (timed — the
//! Fig-6 metric), and optionally the reconstruction error (Fig 4) and the
//! multi-label regression metrics (Fig 5).

use crate::coordinator::{PinvJob, PipelineCoordinator};
use crate::data::{load_dataset, Dataset};
use crate::error::Result;
use crate::pinv::Method;
use crate::regress::{precision_at_k, train_test_split, MultiLabelModel};
use crate::util::rng::Rng;

/// What to compute per cell.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub datasets: Vec<String>,
    pub alphas: Vec<f64>,
    pub methods: Vec<Method>,
    pub scale: f64,
    pub seed: u64,
    /// compute ‖A − UΣVᵀ‖_F (densifies A once per dataset)
    pub reconstruction: bool,
    /// run the 90/10 regression and report P@k
    pub regression: bool,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            datasets: super::DEFAULT_DATASETS.iter().map(|s| s.to_string()).collect(),
            alphas: super::DEFAULT_ALPHAS.to_vec(),
            methods: Method::PAPER_SET.to_vec(),
            scale: super::DEFAULT_SCALE,
            seed: 42,
            reconstruction: false,
            regression: false,
        }
    }
}

impl SweepConfig {
    /// Honour FASTPI_BENCH_FAST: fewer datasets and α points for smoke runs.
    pub fn apply_fast_env(mut self) -> Self {
        if std::env::var("FASTPI_BENCH_FAST").is_ok() {
            self.datasets.truncate(2);
            self.alphas = vec![0.1, 0.4, 1.0];
            self.scale = self.scale.min(0.05);
        }
        self
    }
}

/// One sweep cell result.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub dataset: String,
    pub method: &'static str,
    pub alpha: f64,
    pub rank: usize,
    pub svd_secs: f64,
    pub recon_error: Option<f64>,
    pub p_at_1: Option<f64>,
    pub p_at_3: Option<f64>,
    pub p_at_5: Option<f64>,
}

/// Run the sweep; `emit` is called after every cell (for live table output).
pub fn run_sweep(cfg: &SweepConfig, mut emit: impl FnMut(&SweepRow)) -> Result<Vec<SweepRow>> {
    let coord = PipelineCoordinator::new();
    let mut rows = Vec::new();
    for ds_name in &cfg.datasets {
        let ds: Dataset = load_dataset(ds_name, cfg.scale, cfg.seed, None)?;
        // one split per dataset, shared across methods/alphas so Fig-5
        // differences come from the pseudoinverse, not the split
        let mut split_rng = Rng::seed_from_u64(cfg.seed ^ 0x5117);
        let split = train_test_split(&ds.a, &ds.y, 0.1, &mut split_rng);
        let a_eval = if cfg.regression { &split.a_train } else { &ds.a };
        let dense = if cfg.reconstruction { Some(a_eval.to_dense()) } else { None };

        for &alpha in &cfg.alphas {
            for &method in &cfg.methods {
                let job = PinvJob { method, alpha, k: ds.k, seed: cfg.seed };
                let report = coord.run(a_eval, &job)?;
                let recon_error =
                    dense.as_ref().map(|d| report.svd.reconstruction_error(d));
                let (mut p1, mut p3, mut p5) = (None, None, None);
                if cfg.regression {
                    let (model, _) = MultiLabelModel::train(&report.pinv, &split.y_train);
                    let scores = model.predict(&split.a_test);
                    p1 = Some(precision_at_k(&scores, &split.y_test, 1));
                    p3 = Some(precision_at_k(&scores, &split.y_test, 3));
                    p5 = Some(precision_at_k(&scores, &split.y_test, 5));
                }
                let row = SweepRow {
                    dataset: ds_name.clone(),
                    method: method.name(),
                    alpha,
                    rank: report.rank,
                    svd_secs: report.svd_secs,
                    recon_error,
                    p_at_1: p1,
                    p_at_3: p3,
                    p_at_5: p5,
                };
                emit(&row);
                rows.push(row);
            }
        }
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_sweep_produces_grid() {
        let cfg = SweepConfig {
            datasets: vec!["bibtex".into()],
            alphas: vec![0.2, 0.5],
            methods: vec![Method::FastPi, Method::RandPi],
            scale: 0.03,
            seed: 7,
            reconstruction: true,
            regression: true,
        };
        let mut seen = 0;
        let rows = run_sweep(&cfg, |_| seen += 1).unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(seen, 4);
        for r in &rows {
            assert!(r.svd_secs > 0.0);
            assert!(r.recon_error.unwrap() >= 0.0);
            assert!(r.p_at_3.unwrap() >= 0.0 && r.p_at_3.unwrap() <= 1.0);
            assert!(r.rank > 0);
        }
        // same alpha ⇒ similar error across methods (Figure 4's claim)
        let e_fast = rows[0].recon_error.unwrap();
        let e_rand = rows[1].recon_error.unwrap();
        assert!((e_fast - e_rand).abs() < 0.35 * e_rand.max(e_fast).max(1e-9),
            "fast {e_fast} vs rand {e_rand}");
    }
}
