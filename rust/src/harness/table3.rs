//! Table 3 — dataset statistics: m, n, L, |A|, sp(A), sp(Y), k, m₂, n₂.
//! m₂/n₂ are *outputs* of Algorithm 2 (hub instance/feature node counts),
//! so this harness also runs the reordering.

use crate::data::load_dataset;
use crate::error::Result;
use crate::reorder::{reorder, ReorderConfig};

/// One Table-3 row.
#[derive(Debug, Clone)]
pub struct Table3Row {
    pub dataset: String,
    pub m: usize,
    pub n: usize,
    pub labels: usize,
    pub nnz: usize,
    pub sp_a: f64,
    pub sp_y: f64,
    pub k: f64,
    pub m2: usize,
    pub n2: usize,
    pub iterations: usize,
    pub blocks: usize,
}

/// Build Table 3 for the given datasets at `scale`.
pub fn table3(datasets: &[String], scale: f64, seed: u64) -> Result<Vec<Table3Row>> {
    let mut rows = Vec::new();
    for name in datasets {
        let ds = load_dataset(name, scale, seed, None)?;
        let (m, n, labels, nnz, sp_a, sp_y) = ds.stats();
        let r = reorder(&ds.a, &ReorderConfig { k: ds.k, max_iters: 1000 });
        rows.push(Table3Row {
            dataset: name.clone(),
            m,
            n,
            labels,
            nnz,
            sp_a,
            sp_y,
            k: ds.k,
            m2: r.m2,
            n2: r.n2,
            iterations: r.iterations(),
            blocks: r.blocks.len(),
        });
    }
    Ok(rows)
}

/// Render rows as an aligned text table (the CLI output).
pub fn render(rows: &[Table3Row]) -> String {
    let mut out = String::from(
        "dataset     m        n       L       |A|       sp(A)    sp(Y)    k      m2      n2      iters  blocks\n",
    );
    for r in rows {
        out.push_str(&format!(
            "{:<10} {:>8} {:>7} {:>7} {:>9} {:>8.4} {:>8.4} {:>6.3} {:>7} {:>7} {:>6} {:>7}\n",
            r.dataset, r.m, r.n, r.labels, r.nnz, r.sp_a, r.sp_y, r.k, r.m2, r.n2, r.iterations, r.blocks
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_rows_for_all_datasets() {
        let names: Vec<String> = ["bibtex", "rcv"].iter().map(|s| s.to_string()).collect();
        let rows = table3(&names, 0.03, 3).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.sp_a > 0.5 && r.sp_a < 1.0, "{} sp {}", r.dataset, r.sp_a);
            assert!(r.m2 < r.m && r.n2 < r.n, "hub counts bounded");
            assert!(r.m2 > 0, "some hubs found");
            assert!(r.blocks > 0, "some spokes found");
        }
        let text = render(&rows);
        assert!(text.contains("bibtex"));
        assert!(text.lines().count() >= 3);
    }
}
