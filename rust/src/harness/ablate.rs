//! Ablations of FastPI's design choices (DESIGN.md §6): the reordering
//! itself, the per-block SVD of A11, the hub ratio k, and the inner SVD
//! engine of the incremental updates.

use crate::data::load_dataset;
use crate::dense::svd_truncated;
use crate::error::Result;
use crate::pinv::{fastpi_svd, FastPiConfig};
use crate::reorder::{reorder, ReorderConfig};
use crate::svdlr::{block_diag_svd, InnerSvd};
use crate::util::rng::Rng;
use std::time::Instant;

/// (a) Reordering on/off: FastPI vs the same inner engine applied to the
/// whole matrix without any reorder/split. Returns (fastpi_secs, flat_secs,
/// fastpi_err, flat_err) on the densified matrix.
pub fn ablate_reorder(
    dataset: &str,
    scale: f64,
    alpha: f64,
    seed: u64,
) -> Result<(f64, f64, f64, f64)> {
    let ds = load_dataset(dataset, scale, seed, None)?;
    let dense = ds.a.to_dense();
    let r = ((alpha * ds.a.cols() as f64).ceil() as usize).max(1);

    let mut rng = Rng::seed_from_u64(seed);
    let t = Instant::now();
    let cfg = FastPiConfig { alpha, k: ds.k, ..Default::default() };
    let fast = fastpi_svd(&ds.a, &cfg, &mut rng)?;
    let fast_secs = t.elapsed().as_secs_f64();
    let fast_err = fast.svd.reconstruction_error(&dense);

    let mut rng = Rng::seed_from_u64(seed);
    let t = Instant::now();
    let flat = InnerSvd::Auto.run(&dense, r, &mut rng);
    let flat_secs = t.elapsed().as_secs_f64();
    let flat_err = flat.reconstruction_error(&dense);

    Ok((fast_secs, flat_secs, fast_err, flat_err))
}

/// (b) Block-diagonal SVD vs one monolithic dense SVD of A11.
/// Returns (block_secs, mono_secs, block_err, mono_err) measured on A11.
pub fn ablate_block_svd(
    dataset: &str,
    scale: f64,
    alpha: f64,
    seed: u64,
) -> Result<(f64, f64, f64, f64)> {
    let ds = load_dataset(dataset, scale, seed, None)?;
    let r = reorder(&ds.a, &ReorderConfig { k: ds.k, max_iters: 1000 });
    let b = r.apply(&ds.a);

    let t = Instant::now();
    let f_block = block_diag_svd(&b, &r.blocks, r.m1, r.n1, alpha);
    let block_secs = t.elapsed().as_secs_f64();

    let a11 = b.block_dense(0, 0, r.m1, r.n1);
    let target = ((alpha * r.n1 as f64).ceil() as usize).clamp(1, r.m1.min(r.n1).max(1));
    let t = Instant::now();
    let f_mono = svd_truncated(&a11, target);
    let mono_secs = t.elapsed().as_secs_f64();

    let block_err = f_block.reconstruction_error(&a11);
    let mono_err = f_mono.reconstruction_error(&a11);
    Ok((block_secs, mono_secs, block_err, mono_err))
}

/// (c) Hub-ratio sweep: k → (secs, m2, n2, blocks, iters).
pub fn ablate_hub_ratio(
    dataset: &str,
    scale: f64,
    alpha: f64,
    ks: &[f64],
    seed: u64,
) -> Result<Vec<(f64, f64, usize, usize, usize, usize)>> {
    let ds = load_dataset(dataset, scale, seed, None)?;
    let mut out = Vec::new();
    for &k in ks {
        let mut rng = Rng::seed_from_u64(seed);
        let cfg = FastPiConfig { alpha, k, ..Default::default() };
        let t = Instant::now();
        let f = fastpi_svd(&ds.a, &cfg, &mut rng)?;
        let secs = t.elapsed().as_secs_f64();
        let r = &f.reordering;
        out.push((k, secs, r.m2, r.n2, r.blocks.len(), r.iterations()));
    }
    Ok(out)
}

/// (d) Inner-engine choice at a given α: Dense vs FrPca vs Auto.
/// Returns (engine name, secs, reconstruction error).
pub fn ablate_inner_engine(
    dataset: &str,
    scale: f64,
    alpha: f64,
    seed: u64,
) -> Result<Vec<(&'static str, f64, f64)>> {
    let ds = load_dataset(dataset, scale, seed, None)?;
    let dense = ds.a.to_dense();
    let mut out = Vec::new();
    for (name, inner) in
        [("dense", InnerSvd::Dense), ("frpca", InnerSvd::FrPca), ("auto", InnerSvd::Auto)]
    {
        let mut rng = Rng::seed_from_u64(seed);
        let cfg = FastPiConfig { alpha, k: ds.k, inner, ..Default::default() };
        let t = Instant::now();
        let f = fastpi_svd(&ds.a, &cfg, &mut rng)?;
        out.push((name, t.elapsed().as_secs_f64(), f.svd.reconstruction_error(&dense)));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reorder_ablation_errors_comparable() {
        let (fs, ss, fe, se) = ablate_reorder("bibtex", 0.03, 0.5, 1).unwrap();
        assert!(fs > 0.0 && ss > 0.0);
        // both produce rank-r approximations of similar quality
        assert!((fe - se).abs() < 0.5 * se.max(fe).max(1e-9), "err {fe} vs {se}");
    }

    #[test]
    fn block_svd_matches_monolithic_quality() {
        let (bs, ms, be, me) = ablate_block_svd("rcv", 0.03, 1.0, 1).unwrap();
        assert!(bs > 0.0 && ms > 0.0);
        // at α=1 both are (near-)exact on A11
        assert!(be < 1e-6 + me * 1.05, "block err {be} vs mono {me}");
    }

    #[test]
    fn hub_ratio_sweep_shapes() {
        let rows = ablate_hub_ratio("bibtex", 0.03, 0.3, &[0.01, 0.05], 1).unwrap();
        assert_eq!(rows.len(), 2);
        // larger k ⇒ fewer iterations
        assert!(rows[1].5 <= rows[0].5, "iters {} vs {}", rows[1].5, rows[0].5);
    }

    #[test]
    fn inner_engines_all_valid() {
        let rows = ablate_inner_engine("bibtex", 0.03, 0.2, 1).unwrap();
        assert_eq!(rows.len(), 3);
        let errs: Vec<f64> = rows.iter().map(|r| r.2).collect();
        let lo = errs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = errs.iter().cloned().fold(0.0f64, f64::max);
        assert!(hi < lo * 1.25 + 1e-9, "inner engines diverge: {rows:?}");
    }
}
