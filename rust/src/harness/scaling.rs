//! Table 2 / Lemma 1 — empirical complexity scaling. Measures FastPI
//! wall-clock as one problem dimension grows with the others fixed, and
//! fits the log-log slope: time ∝ m^a at fixed rank (Lemma 1 predicts the
//! dominant term mr², i.e. a ≈ 1), and time ∝ r^b at fixed m (b ≈ 2).

use crate::coordinator::{PinvJob, PipelineCoordinator};
use crate::data::{generate, SynthConfig};
use crate::error::Result;
use crate::pinv::Method;
use crate::util::rng::Rng;

/// One scaling measurement.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub axis: &'static str,
    pub value: usize,
    pub secs: f64,
}

/// Sweep m (rows) at fixed n and α.
pub fn sweep_m(ms: &[usize], n: usize, alpha: f64, seed: u64) -> Result<Vec<ScalePoint>> {
    let coord = PipelineCoordinator::new();
    let mut out = Vec::new();
    for &m in ms {
        let cfg = SynthConfig { m, n, labels: 16, nnz: 6 * m, ..Default::default() };
        let mut rng = Rng::seed_from_u64(seed);
        let (a, _y) = generate(&cfg, &mut rng);
        let job = PinvJob { method: Method::FastPi, alpha, k: 0.01, seed };
        let r = coord.run(&a, &job)?;
        out.push(ScalePoint { axis: "m", value: m, secs: r.svd_secs });
    }
    Ok(out)
}

/// Sweep α (hence rank r = ⌈αn⌉) at fixed matrix size.
pub fn sweep_alpha(alphas: &[f64], m: usize, n: usize, seed: u64) -> Result<Vec<ScalePoint>> {
    let coord = PipelineCoordinator::new();
    let cfg = SynthConfig { m, n, labels: 16, nnz: 6 * m, ..Default::default() };
    let mut rng = Rng::seed_from_u64(seed);
    let (a, _y) = generate(&cfg, &mut rng);
    let mut out = Vec::new();
    for &alpha in alphas {
        let job = PinvJob { method: Method::FastPi, alpha, k: 0.01, seed };
        let r = coord.run(&a, &job)?;
        let rank = ((alpha * n as f64).ceil()) as usize;
        out.push(ScalePoint { axis: "r", value: rank, secs: r.svd_secs });
    }
    Ok(out)
}

/// Least-squares slope of log(secs) vs log(value).
pub fn loglog_slope(points: &[ScalePoint]) -> f64 {
    let n = points.len() as f64;
    assert!(n >= 2.0);
    let xs: Vec<f64> = points.iter().map(|p| (p.value as f64).ln()).collect();
    let ys: Vec<f64> = points.iter().map(|p| p.secs.max(1e-9).ln()).collect();
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let cov: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let var: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    cov / var.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slope_fit_exact_on_synthetic() {
        // secs = value^2 exactly ⇒ slope 2
        let pts: Vec<ScalePoint> = [10usize, 20, 40, 80]
            .iter()
            .map(|&v| ScalePoint { axis: "r", value: v, secs: (v * v) as f64 })
            .collect();
        assert!((loglog_slope(&pts) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn sweeps_run() {
        let pm = sweep_m(&[200, 400], 60, 0.3, 1).unwrap();
        assert_eq!(pm.len(), 2);
        assert!(pm.iter().all(|p| p.secs > 0.0));
        let pa = sweep_alpha(&[0.2, 0.6], 300, 60, 1).unwrap();
        assert_eq!(pa.len(), 2);
        assert!(pa[0].value < pa[1].value);
    }
}
