//! Experiment harnesses — one runner per paper table/figure (DESIGN.md §6).
//! Shared by the `fastpi` CLI and the `benches/` targets, so every number in
//! EXPERIMENTS.md is regenerable from two entry points.

pub mod ablate;
pub mod figures;
pub mod scaling;
pub mod sweep;
pub mod table3;

pub use sweep::{SweepConfig, SweepRow};

/// Default datasets for experiment sweeps.
pub const DEFAULT_DATASETS: [&str; 4] = ["amazon", "rcv", "eurlex", "bibtex"];

/// Default α grid (the paper sweeps 0.01 … 1.0).
pub const DEFAULT_ALPHAS: [f64; 7] = [0.01, 0.05, 0.1, 0.2, 0.4, 0.7, 1.0];

/// Default scale for CI-speed runs (full-size = 1.0; see DESIGN.md §5).
pub const DEFAULT_SCALE: f64 = 0.1;
