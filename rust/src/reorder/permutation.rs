//! Permutation-array helpers. Convention throughout the crate:
//! `perm[old] = new` (a permutation maps an old index to its new position).

use crate::error::{Error, Result};

/// Check that `perm` is a valid permutation of 0..n.
pub fn validate(perm: &[usize]) -> Result<()> {
    let n = perm.len();
    let mut seen = vec![false; n];
    for &p in perm {
        if p >= n {
            return Err(Error::Invalid(format!("permutation value {p} out of range {n}")));
        }
        if seen[p] {
            return Err(Error::Invalid(format!("duplicate permutation value {p}")));
        }
        seen[p] = true;
    }
    Ok(())
}

/// Inverse permutation: if `perm[old] = new` then `inv[new] = old`.
pub fn invert(perm: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; perm.len()];
    for (old, &new) in perm.iter().enumerate() {
        inv[new] = old;
    }
    inv
}

/// Identity permutation.
pub fn identity(n: usize) -> Vec<usize> {
    (0..n).collect()
}

/// Apply to a dense vector: out[perm[i]] = v[i].
pub fn apply<T: Clone + Default>(perm: &[usize], v: &[T]) -> Vec<T> {
    assert_eq!(perm.len(), v.len());
    let mut out = vec![T::default(); v.len()];
    for (old, &new) in perm.iter().enumerate() {
        out[new] = v[old].clone();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;

    #[test]
    fn validate_accepts_good_rejects_bad() {
        assert!(validate(&[2, 0, 1]).is_ok());
        assert!(validate(&[0, 0, 1]).is_err());
        assert!(validate(&[0, 3]).is_err());
        assert!(validate(&[]).is_ok());
    }

    #[test]
    fn invert_roundtrip() {
        check("perm inverse roundtrip", 20, |rng| {
            let n = rng.usize_range(1, 50);
            let p = rng.permutation(n);
            let inv = invert(&p);
            for i in 0..n {
                assert_eq!(inv[p[i]], i);
                assert_eq!(p[inv[i]], i);
            }
        });
    }

    #[test]
    fn apply_moves_values() {
        let p = vec![2usize, 0, 1];
        let v = vec![10, 20, 30];
        assert_eq!(apply(&p, &v), vec![20, 30, 10]);
    }
}
