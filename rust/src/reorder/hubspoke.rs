//! Hub-and-spoke matrix reordering — Algorithm 2 of the paper.
//!
//! Iteratively removes the top-k fraction of highest-degree ("hub")
//! instance and feature nodes, assigns the resulting small disconnected
//! components ("spokes") the lowest ids and the hubs the highest, and
//! recurses on the giant connected component (GCC). The reordered matrix
//! concentrates its non-zeros bottom-right, leaving a large sparse
//! rectangular block-diagonal submatrix A11 top-left.

use crate::graph::{connected_components, Bipartite, NodeId};
use crate::sparse::Csr;

/// Reordering parameters.
#[derive(Debug, Clone)]
pub struct ReorderConfig {
    /// hub selection ratio 0 < k < 1 (paper uses 0.01)
    pub k: f64,
    /// safety cap on iterations (paper's loop terminates naturally)
    pub max_iters: usize,
}

impl Default for ReorderConfig {
    fn default() -> Self {
        ReorderConfig { k: 0.01, max_iters: 1000 }
    }
}

/// A rectangular diagonal block of A11 (one spoke component).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockInfo {
    pub row_start: usize,
    pub row_len: usize,
    pub col_start: usize,
    pub col_len: usize,
}

impl BlockInfo {
    pub fn is_empty(&self) -> bool {
        self.row_len == 0 || self.col_len == 0
    }
}

/// Per-iteration diagnostics (Figure 2/3 evidence).
#[derive(Debug, Clone)]
pub struct IterTrace {
    pub iter: usize,
    pub m_hub: usize,
    pub n_hub: usize,
    /// spoke nodes shed this iteration
    pub spoke_insts: usize,
    pub spoke_feats: usize,
    /// number of non-giant components this iteration
    pub num_spoke_comps: usize,
    /// GCC size after removal
    pub gcc_insts: usize,
    pub gcc_feats: usize,
}

/// Result of Algorithm 2: permutations, the 4-way split sizes, the diagonal
/// block inventory of A11, and the iteration trace.
#[derive(Debug, Clone)]
pub struct Reordering {
    /// row_perm[old_row] = new_row
    pub row_perm: Vec<usize>,
    /// col_perm[old_col] = new_col
    pub col_perm: Vec<usize>,
    /// spoke (A11) extent: rows 0..m1, cols 0..n1
    pub m1: usize,
    pub n1: usize,
    /// hub extent: m2 = m - m1 rows, n2 = n - n1 cols (includes the final
    /// GCC remnant, which is dense-ish and treated as part of the hub block)
    pub m2: usize,
    pub n2: usize,
    /// diagonal blocks of A11, in increasing (row_start, col_start)
    pub blocks: Vec<BlockInfo>,
    pub trace: Vec<IterTrace>,
}

impl Reordering {
    /// Number of reordering iterations performed (T in Lemma 1).
    pub fn iterations(&self) -> usize {
        self.trace.len()
    }

    /// Apply the permutations to the matrix: returns P_r · A · P_cᵀ.
    pub fn apply(&self, a: &Csr) -> Csr {
        a.permute(&self.row_perm, &self.col_perm)
    }
}

/// Run Algorithm 2 on the bipartite view of `a` (paper Definition 1).
pub fn reorder(a: &Csr, cfg: &ReorderConfig) -> Reordering {
    assert!(cfg.k > 0.0 && cfg.k < 1.0, "hub ratio k must be in (0,1)");
    let (m, n) = a.shape();
    let mut g = Bipartite::from_csr(a);

    const UNSET: usize = usize::MAX;
    let mut row_perm = vec![UNSET; m];
    let mut col_perm = vec![UNSET; n];
    // spokes fill from the front, hubs from the back
    let mut next_low_row = 0usize;
    let mut next_low_col = 0usize;
    let mut next_high_row = m; // exclusive
    let mut next_high_col = n;
    let mut blocks: Vec<BlockInfo> = Vec::new();
    let mut trace: Vec<IterTrace> = Vec::new();

    for iter in 0..cfg.max_iters {
        let live_i = g.live_instances();
        let live_f = g.live_features();
        if live_i == 0 && live_f == 0 {
            break;
        }
        let m_hub = ((cfg.k * live_i as f64).ceil() as usize).max(1).min(live_i);
        let n_hub = ((cfg.k * live_f as f64).ceil() as usize).max(1).min(live_f);

        // --- line 2: select hubs by degree (desc), ties by id for determinism
        let hub_insts = top_k_by_degree(g.live_instance_ids(), g.instance_degrees(), m_hub);
        let hub_feats = top_k_by_degree(g.live_feature_ids(), g.feature_degrees(), n_hub);

        // --- line 3: hubs take the highest remaining ids
        // (highest degree gets the highest id, concentrating mass at the corner)
        for &i in &hub_insts {
            next_high_row -= 1;
            row_perm[i] = next_high_row;
        }
        for &j in &hub_feats {
            next_high_col -= 1;
            col_perm[j] = next_high_col;
        }
        for &i in &hub_insts {
            g.remove(NodeId::Instance(i));
        }
        for &j in &hub_feats {
            g.remove(NodeId::Feature(j));
        }

        // --- line 4: BFS components; non-giant components become spokes with
        // the lowest remaining ids; each spoke component is one diagonal
        // block of A11.
        let comps = connected_components(&g);
        let mut spoke_insts = 0usize;
        let mut spoke_feats = 0usize;
        let mut num_spoke_comps = 0usize;
        for (_, (insts, feats)) in comps.non_giant() {
            let block = BlockInfo {
                row_start: next_low_row,
                row_len: insts.len(),
                col_start: next_low_col,
                col_len: feats.len(),
            };
            for &i in insts {
                row_perm[i] = next_low_row;
                next_low_row += 1;
                g.remove(NodeId::Instance(i));
            }
            for &j in feats {
                col_perm[j] = next_low_col;
                next_low_col += 1;
                g.remove(NodeId::Feature(j));
            }
            blocks.push(block);
            spoke_insts += block.row_len;
            spoke_feats += block.col_len;
            num_spoke_comps += 1;
        }

        // --- line 5/6: recurse on the GCC; stop when it is small enough
        let (gcc_i, gcc_f) = match comps.giant {
            Some(gi) => (comps.comps[gi].0.len(), comps.comps[gi].1.len()),
            None => (0, 0),
        };
        trace.push(IterTrace {
            iter,
            m_hub,
            n_hub,
            spoke_insts,
            spoke_feats,
            num_spoke_comps,
            gcc_insts: gcc_i,
            gcc_feats: gcc_f,
        });
        if gcc_i == 0 && gcc_f == 0 {
            break;
        }
        if gcc_i < m_hub || gcc_f < n_hub {
            // terminal GCC remnant: dense-ish — assign into the hub region
            // (middle ids, adjacent to the hubs), lowest degree first so the
            // highest-degree nodes sit nearest the bottom-right corner.
            let mut rem_i = g.live_instance_ids();
            let mut rem_f = g.live_feature_ids();
            let ideg = g.instance_degrees();
            let fdeg = g.feature_degrees();
            rem_i.sort_by_key(|&i| (ideg[i], i));
            rem_f.sort_by_key(|&j| (fdeg[j], j));
            // fill the middle range top-down so ordering matches degree asc
            for &i in rem_i.iter().rev() {
                next_high_row -= 1;
                row_perm[i] = next_high_row;
            }
            for &j in rem_f.iter().rev() {
                next_high_col -= 1;
                col_perm[j] = next_high_col;
            }
            break;
        }
    }

    // Any still-unassigned nodes (max_iters hit) go to the hub region.
    for i in 0..m {
        if row_perm[i] == UNSET {
            next_high_row -= 1;
            row_perm[i] = next_high_row;
        }
    }
    for j in 0..n {
        if col_perm[j] == UNSET {
            next_high_col -= 1;
            col_perm[j] = next_high_col;
        }
    }
    debug_assert_eq!(next_low_row, next_high_row);
    debug_assert_eq!(next_low_col, next_high_col);

    let m1 = next_low_row;
    let n1 = next_low_col;
    Reordering { row_perm, col_perm, m1, n1, m2: m - m1, n2: n - n1, blocks, trace }
}

/// Top-k live node ids by (degree desc, id asc).
fn top_k_by_degree(mut ids: Vec<usize>, degrees: &[usize], k: usize) -> Vec<usize> {
    ids.sort_by(|&a, &b| degrees[b].cmp(&degrees[a]).then(a.cmp(&b)));
    ids.truncate(k);
    ids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reorder::permutation;
    use crate::sparse::Coo;
    use crate::util::propcheck::check;
    use crate::util::rng::Rng;

    /// Random skewed bipartite matrix for property tests.
    fn skewed_matrix(rng: &mut Rng, m: usize, n: usize, nnz: usize) -> Csr {
        let wi: Vec<f64> = (0..m).map(|_| rng.power_law(2.0, m as f64)).collect();
        let wf: Vec<f64> = (0..n).map(|_| rng.power_law(2.0, n as f64)).collect();
        let cum = |w: &[f64]| {
            let mut c = Vec::with_capacity(w.len());
            let mut s = 0.0;
            for &x in w {
                s += x;
                c.push(s);
            }
            c
        };
        let (ci, cf) = (cum(&wi), cum(&wf));
        let mut coo = Coo::new(m, n);
        for _ in 0..nnz {
            coo.push(rng.sample_cumulative(&ci), rng.sample_cumulative(&cf), 1.0);
        }
        Csr::from_coo(&coo)
    }

    #[test]
    fn permutations_are_valid() {
        check("reorder perms valid", 10, |rng| {
            let (m, n) = (rng.usize_range(5, 80), rng.usize_range(5, 60));
            let nnz = rng.usize_range(1, 4 * (m + n));
            let a = skewed_matrix(rng, m, n, nnz);
            let r = reorder(&a, &ReorderConfig { k: 0.05, max_iters: 100 });
            permutation::validate(&r.row_perm).unwrap();
            permutation::validate(&r.col_perm).unwrap();
            assert_eq!(r.m1 + r.m2, m);
            assert_eq!(r.n1 + r.n2, n);
        });
    }

    #[test]
    fn reorder_preserves_matrix() {
        check("reorder preserves entries", 10, |rng| {
            let (m, n) = (rng.usize_range(5, 50), rng.usize_range(5, 50));
            let a = skewed_matrix(rng, m, n, 120);
            let r = reorder(&a, &ReorderConfig::default());
            let b = r.apply(&a);
            assert_eq!(b.nnz(), a.nnz());
            assert!((b.fro_norm() - a.fro_norm()).abs() < 1e-12);
            let ad = a.to_dense();
            let bd = b.to_dense();
            for i in 0..m {
                for j in 0..n {
                    assert_eq!(bd[(r.row_perm[i], r.col_perm[j])], ad[(i, j)]);
                }
            }
        });
    }

    #[test]
    fn blocks_tile_a11_and_cover_its_nnz() {
        check("A11 block-diagonal structure", 10, |rng| {
            let (m, n) = (rng.usize_range(10, 80), rng.usize_range(10, 60));
            let a = skewed_matrix(rng, m, n, 150);
            let r = reorder(&a, &ReorderConfig { k: 0.05, max_iters: 100 });
            let b = r.apply(&a);

            // blocks tile [0,m1) x [0,n1): contiguous, disjoint, in order
            let mut row_cursor = 0usize;
            let mut col_cursor = 0usize;
            for blk in &r.blocks {
                assert_eq!(blk.row_start, row_cursor);
                assert_eq!(blk.col_start, col_cursor);
                row_cursor += blk.row_len;
                col_cursor += blk.col_len;
            }
            assert_eq!(row_cursor, r.m1);
            assert_eq!(col_cursor, r.n1);

            // every nnz of A11 lies inside some diagonal block
            let nnz_a11 = b.nnz_in_region(0, 0, r.m1, r.n1);
            let nnz_blocks: usize = r
                .blocks
                .iter()
                .map(|blk| b.nnz_in_region(blk.row_start, blk.col_start, blk.row_len, blk.col_len))
                .sum();
            assert_eq!(nnz_a11, nnz_blocks, "off-block nnz inside A11");
        });
    }

    #[test]
    fn hubs_concentrate_nnz_bottom_right() {
        let mut rng = Rng::seed_from_u64(77);
        let a = skewed_matrix(&mut rng, 400, 300, 2500);
        let r = reorder(&a, &ReorderConfig::default());
        let b = r.apply(&a);
        // The A11 region must be far sparser than the matrix average:
        // density(A11) << density(A) — that is the entire point of FastPI.
        let area_a11 = (r.m1 * r.n1).max(1);
        let dens_a11 = b.nnz_in_region(0, 0, r.m1, r.n1) as f64 / area_a11 as f64;
        let dens_all = a.nnz() as f64 / (400.0 * 300.0);
        assert!(
            dens_a11 < dens_all,
            "A11 density {dens_a11} should be below matrix density {dens_all}"
        );
        // and the hub corner (A22) must be denser than average
        let area_a22 = (r.m2 * r.n2).max(1);
        let dens_a22 = b.nnz_in_region(r.m1, r.n1, r.m2, r.n2) as f64 / area_a22 as f64;
        assert!(dens_a22 > dens_all, "A22 density {dens_a22} vs {dens_all}");
    }

    #[test]
    fn trace_records_iterations() {
        let mut rng = Rng::seed_from_u64(78);
        let a = skewed_matrix(&mut rng, 200, 150, 1200);
        let r = reorder(&a, &ReorderConfig::default());
        assert!(!r.trace.is_empty());
        for (t, tr) in r.trace.iter().enumerate() {
            assert_eq!(tr.iter, t);
            assert!(tr.m_hub >= 1 && tr.n_hub >= 1);
        }
        // GCC shrinks monotonically
        for w in r.trace.windows(2) {
            assert!(w[1].gcc_insts + w[1].gcc_feats <= w[0].gcc_insts + w[0].gcc_feats);
        }
    }

    #[test]
    fn diagonal_matrix_fully_shatters() {
        // A diagonal matrix has no giant component: everything becomes spokes
        // after the first hub removal round.
        let mut coo = Coo::new(10, 10);
        for i in 0..10 {
            coo.push(i, i, 1.0);
        }
        let a = Csr::from_coo(&coo);
        let r = reorder(&a, &ReorderConfig { k: 0.1, max_iters: 10 });
        // all mass in A11 + small hub remainder
        assert!(r.m1 >= 8, "m1 = {}", r.m1);
        let b = r.apply(&a);
        assert_eq!(b.nnz(), 10);
    }

    #[test]
    fn empty_and_tiny_matrices() {
        let a = Csr::zeros(3, 3);
        let r = reorder(&a, &ReorderConfig::default());
        permutation::validate(&r.row_perm).unwrap();
        assert_eq!(r.m1 + r.m2, 3);

        let mut coo = Coo::new(1, 1);
        coo.push(0, 0, 5.0);
        let a = Csr::from_coo(&coo);
        let r = reorder(&a, &ReorderConfig::default());
        assert_eq!(r.apply(&a).nnz(), 1);
    }
}
