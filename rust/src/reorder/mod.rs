//! Matrix reordering (Algorithm 2) and permutation utilities.

pub mod hubspoke;
pub mod permutation;

pub use hubspoke::{reorder, BlockInfo, IterTrace, ReorderConfig, Reordering};
