//! Data-parallel primitives built on `std::thread::scope`.
//!
//! The environment has no `rayon`, so we provide the two shapes the library
//! needs: an index-space parallel-for with atomic work stealing, and a
//! parallel map over items. Thread count comes from [`num_threads`], settable
//! once per process (CLI `--threads`, env `FASTPI_THREADS`, default = cores).

use once_cell::sync::OnceCell;
use std::sync::atomic::{AtomicUsize, Ordering};

static NUM_THREADS: OnceCell<usize> = OnceCell::new();

/// Set the global worker count. First caller wins; returns false if already set.
pub fn set_num_threads(n: usize) -> bool {
    NUM_THREADS.set(n.max(1)).is_ok()
}

/// Worker count: explicit setting > `FASTPI_THREADS` env > available cores.
pub fn num_threads() -> usize {
    *NUM_THREADS.get_or_init(|| {
        if let Ok(v) = std::env::var("FASTPI_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Parallel for over `0..n` in chunks of `chunk` indices, work-stolen off a
/// shared atomic counter. `f` must be `Sync` (called concurrently).
///
/// Runs inline when `n` is small or only one thread is configured, so it is
/// safe to use unconditionally in numeric kernels.
pub fn for_each_chunk<F>(n: usize, chunk: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let chunk = chunk.max(1);
    let threads = num_threads().min(n.div_ceil(chunk)).max(1);
    if threads == 1 || n == 0 {
        let mut i = 0;
        while i < n {
            f(i..(i + chunk).min(n));
            i += chunk;
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = counter.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                f(start..(start + chunk).min(n));
            });
        }
    });
}

/// Parallel for over single indices (chunk size 1) — for coarse jobs like
/// per-block SVDs where each iteration is substantial.
pub fn for_each_index<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    for_each_chunk(n, 1, |r| {
        for i in r {
            f(i)
        }
    });
}

/// Parallel map: applies `f` to every item of `items`, preserving order.
pub fn map<T: Sync, U: Send, F>(items: &[T], f: F) -> Vec<U>
where
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    {
        let slots = SyncSlots(out.as_mut_ptr());
        let slots_ref = &slots;
        for_each_index(n, move |i| {
            let v = f(&items[i]);
            // SAFETY: each index i is visited exactly once across all workers
            // (atomic counter hand-out), so writes are disjoint.
            unsafe { std::ptr::write(slots_ref.0.add(i), Some(v)) };
        });
    }
    out.into_iter().map(|o| o.expect("slot filled")).collect()
}

/// Wrapper making a raw pointer Sync for the disjoint-write pattern above.
struct SyncSlots<U>(*mut Option<U>);
unsafe impl<U: Send> Sync for SyncSlots<U> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn for_each_index_visits_each_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        for_each_index(1000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn for_each_chunk_covers_range_exactly() {
        let total = AtomicU64::new(0);
        for_each_chunk(1003, 64, |r| {
            let s: u64 = r.map(|i| i as u64).sum();
            total.fetch_add(s, Ordering::Relaxed);
        });
        let expect: u64 = (0..1003u64).sum();
        assert_eq!(total.load(Ordering::Relaxed), expect);
    }

    #[test]
    fn map_preserves_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = map(&items, |&x| x * 3);
        assert_eq!(out, (0..257).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn zero_items_ok() {
        for_each_index(0, |_| panic!("should not run"));
        let out: Vec<u8> = map(&[] as &[u8], |x| *x);
        assert!(out.is_empty());
    }
}
