//! Wall-clock benchmarking harness (no `criterion` in the offline
//! environment). Provides warmup + repeated timing with robust statistics,
//! and a table/CSV reporter shared by all `benches/*.rs` targets.

use std::time::{Duration, Instant};

/// Statistics over a set of timed iterations.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub max_s: f64,
}

impl Stats {
    pub fn from_samples(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        // total_cmp: a NaN sample (e.g. a zero-duration rate division)
        // sorts last instead of panicking the whole bench run
        xs.sort_by(|a, b| a.total_cmp(b));
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| xs[(((n - 1) as f64) * p).round() as usize];
        Stats {
            iters: n,
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: xs[0],
            p50_s: pct(0.5),
            p95_s: pct(0.95),
            max_s: xs[n - 1],
        }
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    /// stop adding iterations once total measured time exceeds this budget
    pub time_budget: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 1,
            measure_iters: 5,
            time_budget: Duration::from_secs(30),
        }
    }
}

impl BenchConfig {
    /// Quick config for CI-ish runs, respecting FASTPI_BENCH_FAST env.
    pub fn from_env() -> Self {
        let mut c = BenchConfig::default();
        if std::env::var("FASTPI_BENCH_FAST").is_ok() {
            c.warmup_iters = 0;
            c.measure_iters = 2;
            c.time_budget = Duration::from_secs(5);
        }
        c
    }
}

/// Time `f` under the config; returns stats over the measured runs.
pub fn run<T>(cfg: &BenchConfig, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..cfg.warmup_iters {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(cfg.measure_iters);
    let budget_start = Instant::now();
    for i in 0..cfg.measure_iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64());
        if i >= 1 && budget_start.elapsed() > cfg.time_budget {
            break;
        }
    }
    Stats::from_samples(samples)
}

/// A collected result row for the reporter.
#[derive(Debug, Clone)]
pub struct Row {
    pub keys: Vec<(String, String)>,
    pub values: Vec<(String, f64)>,
}

/// Table + CSV reporter. Benches construct one, add rows, then `finish()`
/// prints an aligned table and writes `target/bench_results/<name>.csv`.
pub struct Reporter {
    name: String,
    rows: Vec<Row>,
}

impl Reporter {
    pub fn new(name: &str) -> Self {
        Reporter { name: name.to_string(), rows: Vec::new() }
    }

    pub fn add(&mut self, keys: &[(&str, String)], values: &[(&str, f64)]) {
        self.rows.push(Row {
            keys: keys.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
            values: values.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        });
        // incremental echo so long benches show progress
        let r = self.rows.last().unwrap();
        let k: Vec<String> = r.keys.iter().map(|(k, v)| format!("{k}={v}")).collect();
        let v: Vec<String> = r.values.iter().map(|(k, x)| format!("{k}={x:.6}")).collect();
        println!("[{}] {} | {}", self.name, k.join(" "), v.join(" "));
    }

    /// Render aligned table text.
    pub fn table(&self) -> String {
        if self.rows.is_empty() {
            return format!("[{}] no rows\n", self.name);
        }
        // header from the widest row (rows may carry heterogeneous values)
        let widest = self
            .rows
            .iter()
            .max_by_key(|r| r.keys.len() + r.values.len())
            .unwrap();
        let mut cols: Vec<String> = Vec::new();
        for (k, _) in &widest.keys {
            cols.push(k.clone());
        }
        for (k, _) in &widest.values {
            cols.push(k.clone());
        }
        let mut grid: Vec<Vec<String>> = vec![cols.clone()];
        for r in &self.rows {
            let mut row: Vec<String> = r.keys.iter().map(|(_, v)| v.clone()).collect();
            row.extend(r.values.iter().map(|(_, v)| format!("{v:.6}")));
            grid.push(row);
        }
        let ncols = grid.iter().map(|r| r.len()).max().unwrap_or(0);
        let widths: Vec<usize> = (0..ncols)
            .map(|c| grid.iter().map(|r| r.get(c).map_or(0, |s| s.len())).max().unwrap_or(0))
            .collect();
        let mut out = format!("== {} ==\n", self.name);
        for (ri, row) in grid.iter().enumerate() {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(c, s)| format!("{s:>w$}", w = widths.get(c).copied().unwrap_or(0)))
                .collect();
            out.push_str(&line.join("  "));
            out.push('\n');
            if ri == 0 {
                out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
                out.push('\n');
            }
        }
        out
    }

    /// Machine-readable summary: one JSON object with the bench name and
    /// every row's keys (strings) and values (numbers) flattened together.
    /// This is what the cross-PR perf-trajectory tooling consumes, so the
    /// schema is deliberately flat and stable.
    pub fn json(&self) -> String {
        let mut out = String::from("{");
        out.push_str(&format!("\"name\":{},\"rows\":[", json_string(&self.name)));
        for (i, r) in self.rows.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('{');
            let mut first = true;
            for (k, v) in &r.keys {
                if !first {
                    out.push(',');
                }
                first = false;
                out.push_str(&format!("{}:{}", json_string(k), json_string(v)));
            }
            for (k, v) in &r.values {
                if !first {
                    out.push(',');
                }
                first = false;
                out.push_str(&format!("{}:{}", json_string(k), json_number(*v)));
            }
            out.push('}');
        }
        out.push_str("]}");
        out
    }

    /// Print the table; write CSV and a `BENCH_<name>.json` summary under
    /// `target/bench_results/`.
    pub fn finish(&self) {
        print!("{}", self.table());
        let dir = std::path::Path::new("target/bench_results");
        let _ = std::fs::create_dir_all(dir);
        let mut csv = String::new();
        if let Some(first) = self.rows.first() {
            let mut hdr: Vec<String> = first.keys.iter().map(|(k, _)| k.clone()).collect();
            hdr.extend(first.values.iter().map(|(k, _)| k.clone()));
            csv.push_str(&hdr.join(","));
            csv.push('\n');
            for r in &self.rows {
                let mut row: Vec<String> = r.keys.iter().map(|(_, v)| v.clone()).collect();
                row.extend(r.values.iter().map(|(_, v)| format!("{v}")));
                csv.push_str(&row.join(","));
                csv.push('\n');
            }
        }
        for (path, body) in [
            (dir.join(format!("{}.csv", self.name)), csv),
            (dir.join(format!("BENCH_{}.json", self.name)), self.json()),
        ] {
            if let Err(e) = std::fs::write(&path, body) {
                eprintln!("warning: could not write {}: {e}", path.display());
            } else {
                println!("wrote {}", path.display());
            }
        }
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON has no NaN/Infinity literals; encode them as null.
fn json_number(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".into()
    }
}

// ---------------------------------------------------------------------------
// Perf-trajectory gate: parse the flat BENCH_*.json schema back in and diff
// a current run against a committed baseline (`bench_baselines/`), failing
// on regressions of named keys. The parser is deliberately tiny — it reads
// only the schema `Reporter::json` writes (strings, finite numbers, null).

/// A parsed `BENCH_<name>.json` document.
#[derive(Debug, Clone)]
pub struct BenchDoc {
    pub name: String,
    pub rows: Vec<Row>,
}

/// Minimal JSON value for the flat bench schema.
#[derive(Debug, Clone)]
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

struct JsonParser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> JsonParser<'a> {
    fn err<T>(&self, what: &str) -> Result<T, String> {
        Err(format!("json: {what} at byte {}", self.i))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        self.skip_ws();
        if self.i < self.b.len() && self.b[self.i] == c {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected `{}`", c as char))
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.b.get(self.i).copied()
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a value"),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        self.skip_ws();
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            self.err(&format!("expected `{lit}`"))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        self.skip_ws();
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("json: bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let Some(&c) = self.b.get(self.i) else {
                return self.err("unterminated string");
            };
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(&e) = self.b.get(self.i) else {
                        return self.err("unterminated escape");
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return self.err("short \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "json: bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "json: bad \\u escape".to_string())?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return self.err("unknown escape"),
                    }
                }
                _ => {
                    // non-ASCII continuation bytes pass through untouched
                    let rest = &self.b[self.i - 1..];
                    let ch_len = utf8_len(c);
                    if rest.len() < ch_len {
                        return self.err("truncated utf-8");
                    }
                    out.push_str(
                        std::str::from_utf8(&rest[..ch_len])
                            .map_err(|_| "json: bad utf-8".to_string())?,
                    );
                    self.i += ch_len - 1;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return self.err("expected `,` or `]`"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut items = Vec::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(items));
        }
        loop {
            let k = self.string()?;
            self.eat(b':')?;
            let v = self.value()?;
            items.push((k, v));
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(items));
                }
                _ => return self.err("expected `,` or `}`"),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Parse a `BENCH_<name>.json` summary back into rows. String-valued
/// entries become keys, numbers become values, `null`s (non-finite at
/// write time) are dropped.
pub fn parse_bench_json(text: &str) -> Result<BenchDoc, String> {
    let mut p = JsonParser { b: text.as_bytes(), i: 0 };
    let Json::Obj(top) = p.value()? else {
        return Err("json: top level must be an object".into());
    };
    let mut name = None;
    let mut rows = Vec::new();
    for (k, v) in top {
        match (k.as_str(), v) {
            ("name", Json::Str(s)) => name = Some(s),
            ("rows", Json::Arr(items)) => {
                for item in items {
                    let Json::Obj(fields) = item else {
                        return Err("json: each row must be an object".into());
                    };
                    let mut row = Row { keys: Vec::new(), values: Vec::new() };
                    for (fk, fv) in fields {
                        match fv {
                            Json::Str(s) => row.keys.push((fk, s)),
                            Json::Num(x) => row.values.push((fk, x)),
                            Json::Null => {}
                            _ => return Err(format!("json: unexpected value for `{fk}`")),
                        }
                    }
                    rows.push(row);
                }
            }
            _ => {}
        }
    }
    Ok(BenchDoc { name: name.ok_or("json: missing `name`")?, rows })
}

/// Whether a smaller value of this metric is the good direction.
/// Latencies, times, drift/error and ratio-style metrics regress upward;
/// throughputs and speedups regress downward.
pub fn lower_is_better(key: &str) -> bool {
    key.ends_with("_ms")
        || key.ends_with("_s")
        || key.ends_with("secs")
        || key.ends_with("ratio")
        || key.contains("err")
        || key.contains("drift")
        || key.contains("skew")
}

/// Diff `current` against `baseline`, gating only the named keys. For each
/// baseline row (matched to a current row by its full string-key set),
/// every gated key must be present and no worse than `max_regress`
/// (fractional) beyond the baseline value. Returns human-readable failure
/// lines; empty = gate passed.
pub fn diff_bench(
    baseline: &BenchDoc,
    current: &BenchDoc,
    gate_keys: &[String],
    max_regress: f64,
) -> Vec<String> {
    let mut failures = Vec::new();
    let key_id = |r: &Row| {
        let mut ks: Vec<String> = r.keys.iter().map(|(k, v)| format!("{k}={v}")).collect();
        ks.sort();
        ks.join(" ")
    };
    for brow in &baseline.rows {
        let gated: Vec<&(String, f64)> =
            brow.values.iter().filter(|(k, _)| gate_keys.iter().any(|g| g == k)).collect();
        if gated.is_empty() {
            continue;
        }
        let id = key_id(brow);
        let Some(crow) = current.rows.iter().find(|r| key_id(r) == id) else {
            failures.push(format!("{}[{id}]: row missing from current results", baseline.name));
            continue;
        };
        for (k, base) in gated {
            let Some((_, cur)) = crow.values.iter().find(|(ck, _)| ck == k) else {
                failures.push(format!("{}[{id}].{k}: key missing from current row", baseline.name));
                continue;
            };
            let regressed = if lower_is_better(k) {
                *cur > base * (1.0 + max_regress)
            } else {
                *cur < base * (1.0 - max_regress)
            };
            if regressed {
                failures.push(format!(
                    "{}[{id}].{k}: {cur:.4} vs baseline {base:.4} (allowed {:.0}% {})",
                    baseline.name,
                    max_regress * 100.0,
                    if lower_is_better(k) { "above" } else { "below" },
                ));
            }
        }
    }
    failures
}

/// Diff every `BENCH_*.json` under `baseline_dir` against its counterpart
/// in `current_dir`. A baseline whose current file is missing is itself a
/// failure — coverage loss must be loud, not silent.
pub fn diff_dirs(
    baseline_dir: &std::path::Path,
    current_dir: &std::path::Path,
    gate_keys: &[String],
    max_regress: f64,
) -> std::io::Result<Vec<String>> {
    let mut failures = Vec::new();
    let mut seen_any = false;
    let mut entries: Vec<_> = std::fs::read_dir(baseline_dir)?
        .collect::<std::io::Result<Vec<_>>>()?
        .into_iter()
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        .collect();
    entries.sort();
    for fname in entries {
        seen_any = true;
        let base_text = std::fs::read_to_string(baseline_dir.join(&fname))?;
        let baseline = match parse_bench_json(&base_text) {
            Ok(d) => d,
            Err(e) => {
                failures.push(format!("{fname}: unparseable baseline: {e}"));
                continue;
            }
        };
        let cur_path = current_dir.join(&fname);
        let cur_text = match std::fs::read_to_string(&cur_path) {
            Ok(t) => t,
            Err(_) => {
                failures.push(format!("{fname}: no current results at {}", cur_path.display()));
                continue;
            }
        };
        match parse_bench_json(&cur_text) {
            Ok(current) => failures.extend(diff_bench(&baseline, &current, gate_keys, max_regress)),
            Err(e) => failures.push(format!("{fname}: unparseable current results: {e}")),
        }
    }
    if !seen_any {
        failures.push(format!("no BENCH_*.json baselines in {}", baseline_dir.display()));
    }
    Ok(failures)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.iters, 5);
        assert!((s.mean_s - 3.0).abs() < 1e-12);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 5.0);
        assert_eq!(s.p50_s, 3.0);
    }

    #[test]
    fn stats_survive_nan_samples() {
        // regression: partial_cmp().unwrap() panicked the sort on any NaN
        // sample; under total order NaN sorts after every finite value
        let s = Stats::from_samples(vec![2.0, f64::NAN, 1.0]);
        assert_eq!(s.iters, 3);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.p50_s, 2.0);
        assert!(s.max_s.is_nan());
    }

    #[test]
    fn run_measures() {
        let cfg = BenchConfig { warmup_iters: 0, measure_iters: 3, time_budget: Duration::from_secs(10) };
        let mut n = 0u64;
        let s = run(&cfg, || {
            n += 1;
            std::thread::sleep(Duration::from_millis(1));
        });
        assert_eq!(s.iters, 3);
        assert!(s.mean_s >= 0.001);
    }

    #[test]
    fn reporter_renders() {
        let mut r = Reporter::new("unit");
        r.add(&[("dataset", "bibtex".into()), ("alpha", "0.1".into())], &[("secs", 1.5)]);
        r.add(&[("dataset", "rcv".into()), ("alpha", "0.2".into())], &[("secs", 2.5)]);
        let t = r.table();
        assert!(t.contains("dataset"));
        assert!(t.contains("bibtex"));
        assert!(t.contains("2.5"));
    }

    #[test]
    fn json_roundtrips_through_the_parser() {
        let mut r = Reporter::new("rt \"quoted\"");
        r.add(
            &[("policy", "batch=64".into()), ("clients", "32".into())],
            &[("throughput_rps", 123.5), ("p95_ms", 4.25), ("bad", f64::NAN)],
        );
        let doc = parse_bench_json(&r.json()).unwrap();
        assert_eq!(doc.name, "rt \"quoted\"");
        assert_eq!(doc.rows.len(), 1);
        let row = &doc.rows[0];
        assert_eq!(row.keys, vec![
            ("policy".to_string(), "batch=64".to_string()),
            ("clients".to_string(), "32".to_string()),
        ]);
        // the NaN was written as null and dropped on re-read
        assert_eq!(row.values.len(), 2);
        assert_eq!(row.values[0], ("throughput_rps".to_string(), 123.5));
        assert!(parse_bench_json("{\"rows\":[]}").is_err(), "missing name must error");
        assert!(parse_bench_json("not json").is_err());
    }

    #[test]
    fn bench_diff_gates_named_keys_in_both_directions() {
        let mk = |rps: f64, p95: f64| BenchDoc {
            name: "serve".into(),
            rows: vec![Row {
                keys: vec![("policy".into(), "batch=64".into())],
                values: vec![("throughput_rps".into(), rps), ("p95_ms".into(), p95)],
            }],
        };
        let gates = vec!["throughput_rps".to_string(), "p95_ms".to_string()];
        let base = mk(100.0, 10.0);
        // within tolerance both ways
        assert!(diff_bench(&base, &mk(85.0, 11.5), &gates, 0.20).is_empty());
        assert!(diff_bench(&base, &mk(500.0, 1.0), &gates, 0.20).is_empty());
        // throughput regresses downward
        let f = diff_bench(&base, &mk(70.0, 10.0), &gates, 0.20);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].contains("throughput_rps"), "{f:?}");
        // latency regresses upward
        let f = diff_bench(&base, &mk(100.0, 13.0), &gates, 0.20);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].contains("p95_ms"), "{f:?}");
        // ungated keys never fire
        let f = diff_bench(&base, &mk(100.0, 99.0), &["throughput_rps".to_string()], 0.20);
        assert!(f.is_empty(), "{f:?}");
        // a missing row is a loud failure
        let empty = BenchDoc { name: "serve".into(), rows: vec![] };
        let f = diff_bench(&base, &empty, &gates, 0.20);
        assert_eq!(f.len(), 1);
        assert!(f[0].contains("row missing"), "{f:?}");
    }

    #[test]
    fn metric_direction_heuristic() {
        for k in ["p95_ms", "secs", "mean_s", "jitter_ratio", "recon_err", "drift", "skew"] {
            assert!(lower_is_better(k), "{k} should regress upward");
        }
        for k in ["throughput_rps", "speedup", "swaps", "p@1"] {
            assert!(!lower_is_better(k), "{k} should regress downward");
        }
    }

    #[test]
    fn reporter_json_summary() {
        let mut r = Reporter::new("unit_json");
        r.add(&[("policy", "batch=64".into())], &[("rps", 100.5), ("bad", f64::NAN)]);
        let j = r.json();
        assert_eq!(
            j,
            r#"{"name":"unit_json","rows":[{"policy":"batch=64","rps":100.5,"bad":null}]}"#
        );
        // escaping
        assert_eq!(json_string("a\"b\\c\n"), r#""a\"b\\c\n""#);
        assert_eq!(json_number(f64::INFINITY), "null");
    }
}
