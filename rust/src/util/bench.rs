//! Wall-clock benchmarking harness (no `criterion` in the offline
//! environment). Provides warmup + repeated timing with robust statistics,
//! and a table/CSV reporter shared by all `benches/*.rs` targets.

use std::time::{Duration, Instant};

/// Statistics over a set of timed iterations.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub max_s: f64,
}

impl Stats {
    pub fn from_samples(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| xs[(((n - 1) as f64) * p).round() as usize];
        Stats {
            iters: n,
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: xs[0],
            p50_s: pct(0.5),
            p95_s: pct(0.95),
            max_s: xs[n - 1],
        }
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    /// stop adding iterations once total measured time exceeds this budget
    pub time_budget: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 1,
            measure_iters: 5,
            time_budget: Duration::from_secs(30),
        }
    }
}

impl BenchConfig {
    /// Quick config for CI-ish runs, respecting FASTPI_BENCH_FAST env.
    pub fn from_env() -> Self {
        let mut c = BenchConfig::default();
        if std::env::var("FASTPI_BENCH_FAST").is_ok() {
            c.warmup_iters = 0;
            c.measure_iters = 2;
            c.time_budget = Duration::from_secs(5);
        }
        c
    }
}

/// Time `f` under the config; returns stats over the measured runs.
pub fn run<T>(cfg: &BenchConfig, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..cfg.warmup_iters {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(cfg.measure_iters);
    let budget_start = Instant::now();
    for i in 0..cfg.measure_iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64());
        if i >= 1 && budget_start.elapsed() > cfg.time_budget {
            break;
        }
    }
    Stats::from_samples(samples)
}

/// A collected result row for the reporter.
#[derive(Debug, Clone)]
pub struct Row {
    pub keys: Vec<(String, String)>,
    pub values: Vec<(String, f64)>,
}

/// Table + CSV reporter. Benches construct one, add rows, then `finish()`
/// prints an aligned table and writes `target/bench_results/<name>.csv`.
pub struct Reporter {
    name: String,
    rows: Vec<Row>,
}

impl Reporter {
    pub fn new(name: &str) -> Self {
        Reporter { name: name.to_string(), rows: Vec::new() }
    }

    pub fn add(&mut self, keys: &[(&str, String)], values: &[(&str, f64)]) {
        self.rows.push(Row {
            keys: keys.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
            values: values.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        });
        // incremental echo so long benches show progress
        let r = self.rows.last().unwrap();
        let k: Vec<String> = r.keys.iter().map(|(k, v)| format!("{k}={v}")).collect();
        let v: Vec<String> = r.values.iter().map(|(k, x)| format!("{k}={x:.6}")).collect();
        println!("[{}] {} | {}", self.name, k.join(" "), v.join(" "));
    }

    /// Render aligned table text.
    pub fn table(&self) -> String {
        if self.rows.is_empty() {
            return format!("[{}] no rows\n", self.name);
        }
        // header from the widest row (rows may carry heterogeneous values)
        let widest = self
            .rows
            .iter()
            .max_by_key(|r| r.keys.len() + r.values.len())
            .unwrap();
        let mut cols: Vec<String> = Vec::new();
        for (k, _) in &widest.keys {
            cols.push(k.clone());
        }
        for (k, _) in &widest.values {
            cols.push(k.clone());
        }
        let mut grid: Vec<Vec<String>> = vec![cols.clone()];
        for r in &self.rows {
            let mut row: Vec<String> = r.keys.iter().map(|(_, v)| v.clone()).collect();
            row.extend(r.values.iter().map(|(_, v)| format!("{v:.6}")));
            grid.push(row);
        }
        let ncols = grid.iter().map(|r| r.len()).max().unwrap_or(0);
        let widths: Vec<usize> = (0..ncols)
            .map(|c| grid.iter().map(|r| r.get(c).map_or(0, |s| s.len())).max().unwrap_or(0))
            .collect();
        let mut out = format!("== {} ==\n", self.name);
        for (ri, row) in grid.iter().enumerate() {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(c, s)| format!("{s:>w$}", w = widths.get(c).copied().unwrap_or(0)))
                .collect();
            out.push_str(&line.join("  "));
            out.push('\n');
            if ri == 0 {
                out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
                out.push('\n');
            }
        }
        out
    }

    /// Machine-readable summary: one JSON object with the bench name and
    /// every row's keys (strings) and values (numbers) flattened together.
    /// This is what the cross-PR perf-trajectory tooling consumes, so the
    /// schema is deliberately flat and stable.
    pub fn json(&self) -> String {
        let mut out = String::from("{");
        out.push_str(&format!("\"name\":{},\"rows\":[", json_string(&self.name)));
        for (i, r) in self.rows.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('{');
            let mut first = true;
            for (k, v) in &r.keys {
                if !first {
                    out.push(',');
                }
                first = false;
                out.push_str(&format!("{}:{}", json_string(k), json_string(v)));
            }
            for (k, v) in &r.values {
                if !first {
                    out.push(',');
                }
                first = false;
                out.push_str(&format!("{}:{}", json_string(k), json_number(*v)));
            }
            out.push('}');
        }
        out.push_str("]}");
        out
    }

    /// Print the table; write CSV and a `BENCH_<name>.json` summary under
    /// `target/bench_results/`.
    pub fn finish(&self) {
        print!("{}", self.table());
        let dir = std::path::Path::new("target/bench_results");
        let _ = std::fs::create_dir_all(dir);
        let mut csv = String::new();
        if let Some(first) = self.rows.first() {
            let mut hdr: Vec<String> = first.keys.iter().map(|(k, _)| k.clone()).collect();
            hdr.extend(first.values.iter().map(|(k, _)| k.clone()));
            csv.push_str(&hdr.join(","));
            csv.push('\n');
            for r in &self.rows {
                let mut row: Vec<String> = r.keys.iter().map(|(_, v)| v.clone()).collect();
                row.extend(r.values.iter().map(|(_, v)| format!("{v}")));
                csv.push_str(&row.join(","));
                csv.push('\n');
            }
        }
        for (path, body) in [
            (dir.join(format!("{}.csv", self.name)), csv),
            (dir.join(format!("BENCH_{}.json", self.name)), self.json()),
        ] {
            if let Err(e) = std::fs::write(&path, body) {
                eprintln!("warning: could not write {}: {e}", path.display());
            } else {
                println!("wrote {}", path.display());
            }
        }
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON has no NaN/Infinity literals; encode them as null.
fn json_number(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.iters, 5);
        assert!((s.mean_s - 3.0).abs() < 1e-12);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 5.0);
        assert_eq!(s.p50_s, 3.0);
    }

    #[test]
    fn run_measures() {
        let cfg = BenchConfig { warmup_iters: 0, measure_iters: 3, time_budget: Duration::from_secs(10) };
        let mut n = 0u64;
        let s = run(&cfg, || {
            n += 1;
            std::thread::sleep(Duration::from_millis(1));
        });
        assert_eq!(s.iters, 3);
        assert!(s.mean_s >= 0.001);
    }

    #[test]
    fn reporter_renders() {
        let mut r = Reporter::new("unit");
        r.add(&[("dataset", "bibtex".into()), ("alpha", "0.1".into())], &[("secs", 1.5)]);
        r.add(&[("dataset", "rcv".into()), ("alpha", "0.2".into())], &[("secs", 2.5)]);
        let t = r.table();
        assert!(t.contains("dataset"));
        assert!(t.contains("bibtex"));
        assert!(t.contains("2.5"));
    }

    #[test]
    fn reporter_json_summary() {
        let mut r = Reporter::new("unit_json");
        r.add(&[("policy", "batch=64".into())], &[("rps", 100.5), ("bad", f64::NAN)]);
        let j = r.json();
        assert_eq!(
            j,
            r#"{"name":"unit_json","rows":[{"policy":"batch=64","rps":100.5,"bad":null}]}"#
        );
        // escaping
        assert_eq!(json_string("a\"b\\c\n"), r#""a\"b\\c\n""#);
        assert_eq!(json_number(f64::INFINITY), "null");
    }
}
