//! In-tree utility substrates (PRNG, CLI, benching, property testing,
//! timing). These replace crates.io dependencies that are not available in
//! the offline build environment — see DESIGN.md §5. Parallelism lives in
//! [`crate::runtime::pool`] (the shared worker-pool runtime).

pub mod args;
pub mod bench;
pub mod hash;
pub mod propcheck;
pub mod rng;
pub mod timer;
