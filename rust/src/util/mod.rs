//! In-tree utility substrates (PRNG, parallelism, CLI, benching, property
//! testing, timing). These replace crates.io dependencies that are not
//! available in the offline build environment — see DESIGN.md §5.

pub mod args;
pub mod bench;
pub mod parallel;
pub mod propcheck;
pub mod rng;
pub mod timer;
