//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so we carry our own
//! xoshiro256++ generator (Blackman & Vigna) seeded through SplitMix64.
//! Everything in the library that needs randomness takes an explicit
//! [`Rng`] so experiments are reproducible from a single `--seed`.

/// xoshiro256++ PRNG. Fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second output of the Box–Muller transform
    gauss_spare: Option<f64>,
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64 step — used to expand a 64-bit seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 expansion).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection-free-ish widening
    /// multiply; bias is negligible for our n << 2^64 but we reject anyway.
    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        assert!(n > 0, "usize_below(0)");
        let n = n as u64;
        // widening multiply rejection sampling
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.usize_below(hi - lo)
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample an index from unnormalized non-negative weights using a
    /// precomputed cumulative sum (caller supplies `cum`, last entry = total).
    /// Binary search: O(log n).
    pub fn sample_cumulative(&mut self, cum: &[f64]) -> usize {
        let total = *cum.last().expect("empty cumulative weights");
        let x = self.f64() * total;
        // first index with cum[idx] > x; total_cmp so a NaN weight (which
        // makes every cum tail NaN) degrades to an in-range pick, not a panic
        match cum.binary_search_by(|c| c.total_cmp(&x)) {
            Ok(i) => (i + 1).min(cum.len() - 1),
            Err(i) => i.min(cum.len() - 1),
        }
    }

    /// Draw from a bounded discrete power law P(d) ∝ d^-gamma for
    /// d in [1, dmax] via inverse-CDF on the continuous approximation.
    pub fn power_law(&mut self, gamma: f64, dmax: f64) -> f64 {
        debug_assert!(gamma > 1.0);
        let u = self.f64();
        let a = 1.0 - gamma;
        // inverse CDF of truncated pareto on [1, dmax]
        let hi = dmax.powf(a);
        (1.0 + u * (hi - 1.0)).powf(1.0 / a)
    }

    /// Split off an independent child generator (for parallel workers).
    pub fn split(&mut self) -> Rng {
        Rng::seed_from_u64(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn usize_below_bounds_and_coverage() {
        let mut r = Rng::seed_from_u64(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.usize_below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn power_law_bounds_and_skew() {
        let mut r = Rng::seed_from_u64(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.power_law(2.0, 1000.0)).collect();
        assert!(xs.iter().all(|&x| (1.0..=1000.0).contains(&x)));
        // median should be small (heavy skew): for gamma=2, median = 2 (approx)
        let mut s = xs.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        assert!(s[n / 2] < 3.0, "median {}", s[n / 2]);
        // but max should be large
        assert!(*s.last().unwrap() > 100.0);
    }

    #[test]
    fn sample_cumulative_respects_weights() {
        let mut r = Rng::seed_from_u64(13);
        let cum = [1.0, 1.0, 4.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.sample_cumulative(&cum)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn sample_cumulative_survives_nan_weights() {
        // regression: a NaN in the cumulative table made binary_search_by
        // panic through partial_cmp().unwrap(); total_cmp keeps the draw
        // in range instead
        let mut r = Rng::seed_from_u64(17);
        let cum = [1.0, f64::NAN, 4.0];
        for _ in 0..1000 {
            assert!(r.sample_cumulative(&cum) < cum.len());
        }
    }
}
