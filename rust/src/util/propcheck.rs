//! Tiny property-based testing helper (no `proptest` in the offline
//! environment). Runs a property over many seeded random cases and reports
//! the failing seed so a case can be replayed deterministically.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla_extension rpath)
//! use fastpi::util::propcheck::check;
//! use fastpi::util::rng::Rng;
//! check("addition commutes", 100, |rng: &mut Rng| {
//!     let (a, b) = (rng.f64(), rng.f64());
//!     assert!((a + b - (b + a)).abs() < 1e-15);
//! });
//! ```

use crate::util::rng::Rng;

/// Base seed; override with FASTPI_PROP_SEED to reproduce a CI failure.
fn base_seed() -> u64 {
    std::env::var("FASTPI_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xFA57_51)
}

/// Number-of-cases multiplier (FASTPI_PROP_CASES=0.1 for a quick pass).
fn case_multiplier() -> f64 {
    std::env::var("FASTPI_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(1.0)
}

/// Run `prop` over `cases` random cases. Each case gets an independent Rng
/// derived from (base_seed, case index); panics propagate with the case id.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Rng) + std::panic::RefUnwindSafe,
{
    let n = ((cases as f64 * case_multiplier()).ceil() as usize).max(1);
    let base = base_seed();
    for case in 0..n {
        let seed = base ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::seed_from_u64(seed);
            prop(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property `{name}` failed at case {case}/{n} (seed {seed:#x}, \
                 rerun with FASTPI_PROP_SEED={base}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("trivial", 25, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_reports_seed() {
        check("fails", 10, |rng| {
            // fail on the first case deterministically
            let _ = rng.f64();
            assert!(false, "intentional");
        });
    }
}
