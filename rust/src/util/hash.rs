//! FNV-1a — the one non-cryptographic byte hash the crate needs, shared by
//! the dataset registry (per-name RNG streams) and the model format
//! (payload checksums) so the constants can never silently diverge.

/// 64-bit FNV-1a over a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // standard FNV-1a test vectors
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
        // order sensitivity
        assert_ne!(fnv1a(b"ab"), fnv1a(b"ba"));
    }
}
