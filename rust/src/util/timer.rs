//! Stage timing for the coordinator and experiment harnesses.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Accumulates named stage durations (insertion-ordered by name).
#[derive(Debug, Default, Clone)]
pub struct StageTimes {
    stages: BTreeMap<String, Duration>,
    order: Vec<String>,
}

impl StageTimes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` under `name`, accumulating across calls.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(name, t.elapsed());
        out
    }

    pub fn add(&mut self, name: &str, d: Duration) {
        if !self.stages.contains_key(name) {
            self.order.push(name.to_string());
        }
        *self.stages.entry(name.to_string()).or_default() += d;
    }

    pub fn get(&self, name: &str) -> Duration {
        self.stages.get(name).copied().unwrap_or_default()
    }

    pub fn total(&self) -> Duration {
        self.stages.values().sum()
    }

    /// Stages in first-recorded order with seconds.
    pub fn rows(&self) -> Vec<(String, f64)> {
        self.order
            .iter()
            .map(|n| (n.clone(), self.stages[n].as_secs_f64()))
            .collect()
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        for (name, secs) in self.rows() {
            s.push_str(&format!("  {name:<28} {secs:>10.4}s\n"));
        }
        s.push_str(&format!("  {:<28} {:>10.4}s\n", "TOTAL", self.total().as_secs_f64()));
        s
    }

    pub fn merge(&mut self, other: &StageTimes) {
        for (name, secs) in other.rows() {
            self.add(&name, Duration::from_secs_f64(secs));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_orders() {
        let mut st = StageTimes::new();
        st.add("b", Duration::from_millis(10));
        st.add("a", Duration::from_millis(5));
        st.add("b", Duration::from_millis(10));
        let rows = st.rows();
        assert_eq!(rows[0].0, "b");
        assert_eq!(rows[1].0, "a");
        assert!((rows[0].1 - 0.020).abs() < 1e-9);
        assert!((st.total().as_secs_f64() - 0.025).abs() < 1e-9);
    }

    #[test]
    fn time_returns_value() {
        let mut st = StageTimes::new();
        let v = st.time("work", || 41 + 1);
        assert_eq!(v, 42);
        assert!(st.get("work") > Duration::ZERO || st.get("work") == Duration::ZERO);
        assert_eq!(st.rows().len(), 1);
    }
}
