//! Minimal CLI argument parser (no `clap` in the offline environment).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed getters and a generated usage block.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Parse error with the offending token.
#[derive(Debug)]
pub struct ArgError(pub String, pub String);

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "bad argument `{}`: {}", self.0, self.1)
    }
}

impl std::error::Error for ArgError {}

impl Args {
    /// Parse a token stream. A `--key` consumes the following token as its
    /// value unless that token also starts with `--` (then `--key` is a flag).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Self {
        let tokens: Vec<String> = it.into_iter().collect();
        let mut a = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(stripped) = t.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    a.opts.insert(stripped.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.push(stripped.to_string());
                }
            } else {
                a.positional.push(t.clone());
            }
            i += 1;
        }
        a
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("warning: could not parse --{name} {v}; using default");
                std::process::exit(2)
            }),
            None => default,
        }
    }

    /// Comma-separated list of T, e.g. `--alphas 0.05,0.1,0.2`.
    pub fn parse_list<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.get(name) {
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim().parse().unwrap_or_else(|_| {
                        eprintln!("error: bad element `{s}` in --{name}");
                        std::process::exit(2)
                    })
                })
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_forms() {
        let a = parse("cmd --alpha 0.3 --scale=0.1 --verbose --out dir");
        assert_eq!(a.positional(), &["cmd".to_string()]);
        assert_eq!(a.get("alpha"), Some("0.3"));
        assert_eq!(a.get("scale"), Some("0.1"));
        assert_eq!(a.get("out"), Some("dir"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_before_flag() {
        let a = parse("--fast --threads 4");
        assert!(a.flag("fast"));
        assert_eq!(a.parse_or("threads", 0usize), 4);
    }

    #[test]
    fn typed_defaults() {
        let a = parse("run");
        assert_eq!(a.parse_or("k", 0.01f64), 0.01);
        assert_eq!(a.str_or("dataset", "bibtex"), "bibtex");
    }

    #[test]
    fn list_parsing() {
        let a = parse("--alphas 0.05,0.1,0.2");
        assert_eq!(a.parse_list("alphas", &[1.0]), vec![0.05, 0.1, 0.2]);
        let b = parse("");
        assert_eq!(b.parse_list("alphas", &[1.0, 2.0]), vec![1.0, 2.0]);
    }
}
