//! CLI dispatch — the leader entrypoint. One subcommand per experiment
//! (DESIGN.md §6) plus operational commands.

use crate::harness::{self, ablate, figures, scaling, sweep, table3};
use crate::pinv::Method;
use crate::util::args::Args;
use crate::util::bench::Reporter;

const USAGE: &str = "\
fastpi — Fast PseudoInverse (Jung & Sael, 2020) reproduction

USAGE: fastpi <command> [options]

EXPERIMENTS (paper artifact regenerators):
  table3     dataset statistics (Table 3)
  fig1       degree distributions (Figure 1)
  fig3       reordering progress + spy plot (Figure 3)
  fig4       reconstruction error sweep (Figure 4)
  fig5       multi-label P@k sweep (Figure 5)
  fig6       running-time sweep (Figure 6)
  scaling    empirical complexity fits (Table 2 / Lemma 1)
  ablate     design-choice ablations

OPERATIONS:
  pinv       compute a pseudoinverse on a dataset and report stages
  serve      start the scoring server on a trained model
  datagen    generate + cache a dataset, print stats
  selftest   quick end-to-end smoke test

COMMON OPTIONS:
  --datasets a,b     datasets (default amazon,rcv,eurlex,bibtex)
  --dataset name     single dataset (fig1/fig3/pinv/serve)
  --alphas 0.1,0.5   target rank ratios
  --alpha 0.3        single ratio
  --scale 0.1        dataset scale factor (1.0 = full Table 3 size)
  --methods a,b      fastpi,randpi,krylovpi,frpca,dense
  --seed 42          RNG seed
  --threads N        worker threads
";

pub fn main() {
    let args = Args::from_env();
    if let Some(t) = args.get("threads") {
        if let Ok(n) = t.parse::<usize>() {
            // fix the shared worker pool's width before the first parallel
            // region spins it up (first configuration wins)
            crate::runtime::pool::configure_threads(n);
        }
    }
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "table3" => cmd_table3(&args),
        "fig1" => cmd_fig1(&args),
        "fig3" => cmd_fig3(&args),
        "fig4" => cmd_sweep(&args, SweepKind::Fig4),
        "fig5" => cmd_sweep(&args, SweepKind::Fig5),
        "fig6" => cmd_sweep(&args, SweepKind::Fig6),
        "scaling" => cmd_scaling(&args),
        "ablate" => cmd_ablate(&args),
        "pinv" => cmd_pinv(&args),
        "serve" => cmd_serve(&args),
        "datagen" => cmd_datagen(&args),
        "selftest" => cmd_selftest(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn datasets_arg(args: &Args) -> Vec<String> {
    args.parse_list(
        "datasets",
        &harness::DEFAULT_DATASETS.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
    )
}

fn methods_arg(args: &Args) -> Vec<Method> {
    match args.get("methods") {
        Some(spec) => spec
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                Method::from_name(s).unwrap_or_else(|| {
                    eprintln!("unknown method `{s}`");
                    std::process::exit(2)
                })
            })
            .collect(),
        None => Method::PAPER_SET.to_vec(),
    }
}

fn cmd_table3(args: &Args) -> crate::error::Result<()> {
    let rows = table3::table3(
        &datasets_arg(args),
        args.parse_or("scale", harness::DEFAULT_SCALE),
        args.parse_or("seed", 42),
    )?;
    print!("{}", table3::render(&rows));
    Ok(())
}

fn cmd_fig1(args: &Args) -> crate::error::Result<()> {
    for ds in resolve_single_or_all(args) {
        let f = figures::fig1(&ds, args.parse_or("scale", harness::DEFAULT_SCALE), args.parse_or("seed", 42))?;
        print!("{}", figures::render_fig1(&f));
    }
    Ok(())
}

fn cmd_fig3(args: &Args) -> crate::error::Result<()> {
    for ds in resolve_single_or_all(args) {
        let f = figures::fig3(&ds, args.parse_or("scale", harness::DEFAULT_SCALE), args.parse_or("seed", 42))?;
        print!("{}", figures::render_fig3(&f));
    }
    Ok(())
}

fn resolve_single_or_all(args: &Args) -> Vec<String> {
    match args.get("dataset") {
        Some(d) => vec![d.to_string()],
        None => datasets_arg(args),
    }
}

enum SweepKind {
    Fig4,
    Fig5,
    Fig6,
}

fn cmd_sweep(args: &Args, kind: SweepKind) -> crate::error::Result<()> {
    let cfg = sweep::SweepConfig {
        datasets: datasets_arg(args),
        alphas: args.parse_list("alphas", &harness::DEFAULT_ALPHAS),
        methods: methods_arg(args),
        scale: args.parse_or("scale", harness::DEFAULT_SCALE),
        seed: args.parse_or("seed", 42),
        reconstruction: matches!(kind, SweepKind::Fig4),
        regression: matches!(kind, SweepKind::Fig5),
    };
    let name = match kind {
        SweepKind::Fig4 => "fig4_reconstruction",
        SweepKind::Fig5 => "fig5_accuracy",
        SweepKind::Fig6 => "fig6_runtime",
    };
    let mut rep = Reporter::new(name);
    sweep::run_sweep(&cfg, |r| {
        let mut vals: Vec<(&str, f64)> = vec![("secs", r.svd_secs), ("rank", r.rank as f64)];
        if let Some(e) = r.recon_error {
            vals.push(("recon_err", e));
        }
        if let Some(p) = r.p_at_1 {
            vals.push(("p@1", p));
        }
        if let Some(p) = r.p_at_3 {
            vals.push(("p@3", p));
        }
        if let Some(p) = r.p_at_5 {
            vals.push(("p@5", p));
        }
        rep.add(
            &[
                ("dataset", r.dataset.clone()),
                ("method", r.method.to_string()),
                ("alpha", format!("{}", r.alpha)),
            ],
            &vals,
        );
    })?;
    rep.finish();
    Ok(())
}

fn cmd_scaling(args: &Args) -> crate::error::Result<()> {
    let seed = args.parse_or("seed", 42);
    let ms = args.parse_list("ms", &[500usize, 1000, 2000, 4000]);
    let pm = scaling::sweep_m(&ms, 200, 0.3, seed)?;
    let mut rep = Reporter::new("table2_scaling");
    for p in &pm {
        rep.add(&[("axis", p.axis.into()), ("value", p.value.to_string())], &[("secs", p.secs)]);
    }
    println!("slope time~m^a: a = {:.2} (Lemma 1 predicts ≈1)", scaling::loglog_slope(&pm));
    let alphas = args.parse_list("alphas", &[0.1, 0.2, 0.4, 0.8]);
    let pa = scaling::sweep_alpha(&alphas, 2000, 400, seed)?;
    for p in &pa {
        rep.add(&[("axis", p.axis.into()), ("value", p.value.to_string())], &[("secs", p.secs)]);
    }
    println!("slope time~r^b: b = {:.2} (Lemma 1 predicts ≈2)", scaling::loglog_slope(&pa));
    rep.finish();
    Ok(())
}

fn cmd_ablate(args: &Args) -> crate::error::Result<()> {
    let scale = args.parse_or("scale", harness::DEFAULT_SCALE);
    let seed = args.parse_or("seed", 42);
    let alpha = args.parse_or("alpha", 0.3);
    let ds = args.str_or("dataset", "bibtex");
    let mut rep = Reporter::new("ablation");

    let (fs, ss, fe, se) = ablate::ablate_reorder(&ds, scale, alpha, seed)?;
    rep.add(&[("ablation", "reorder_on".into())], &[("secs", fs), ("err", fe)]);
    rep.add(&[("ablation", "reorder_off".into())], &[("secs", ss), ("err", se)]);

    let (bs, ms, be, me) = ablate::ablate_block_svd(&ds, scale, alpha, seed)?;
    rep.add(&[("ablation", "block_svd".into())], &[("secs", bs), ("err", be)]);
    rep.add(&[("ablation", "monolithic_a11".into())], &[("secs", ms), ("err", me)]);

    for (k, secs, m2, n2, blocks, iters) in
        ablate::ablate_hub_ratio(&ds, scale, alpha, &[0.005, 0.01, 0.02, 0.05, 0.1], seed)?
    {
        rep.add(
            &[("ablation", format!("hub_k={k}"))],
            &[
                ("secs", secs),
                ("m2", m2 as f64),
                ("n2", n2 as f64),
                ("blocks", blocks as f64),
                ("iters", iters as f64),
            ],
        );
    }

    for (name, secs, err) in ablate::ablate_inner_engine(&ds, scale, alpha, seed)? {
        rep.add(&[("ablation", format!("inner_{name}"))], &[("secs", secs), ("err", err)]);
    }
    rep.finish();
    Ok(())
}

fn cmd_pinv(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{PinvJob, PipelineCoordinator};
    let ds = args.str_or("dataset", "bibtex");
    let method = Method::from_name(&args.str_or("method", "fastpi"))
        .unwrap_or(Method::FastPi);
    let job = PinvJob {
        method,
        alpha: args.parse_or("alpha", 0.3),
        k: args.parse_or("k", 0.01),
        seed: args.parse_or("seed", 42),
    };
    let coord = PipelineCoordinator::new();
    let report =
        coord.run_on_dataset(&ds, args.parse_or("scale", harness::DEFAULT_SCALE), &job)?;
    println!(
        "{} on {ds}: rank={} secs={:.3}\nstages:\n{}",
        report.method,
        report.rank,
        report.svd_secs,
        report.stages.render()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{PinvJob, PipelineCoordinator, ScoreServer, ServerConfig};
    use crate::data::load_dataset;
    use crate::regress::MultiLabelModel;
    let name = args.str_or("dataset", "bibtex");
    let scale = args.parse_or("scale", harness::DEFAULT_SCALE);
    let seed = args.parse_or("seed", 42);
    let ds = load_dataset(&name, scale, seed, None)?;
    let job = PinvJob {
        method: Method::FastPi,
        alpha: args.parse_or("alpha", 0.5),
        k: ds.k,
        seed,
    };
    println!("computing pseudoinverse for {name} (scale {scale})...");
    let report = PipelineCoordinator::new().run(&ds.a, &job)?;
    let (model, _) = MultiLabelModel::train(&report.pinv, &ds.y);
    let server_cfg = ServerConfig {
        threads: args.parse_or("threads", 0usize),
        ..Default::default()
    };
    let server = ScoreServer::start(model, server_cfg).map_err(crate::error::Error::Io)?;
    println!("scoring server on {} — protocol: SCORE <topk> j:v,...  (Ctrl-C to stop)", server.addr);
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_datagen(args: &Args) -> crate::error::Result<()> {
    use crate::data::load_dataset;
    for name in datasets_arg(args) {
        let ds = load_dataset(
            &name,
            args.parse_or("scale", harness::DEFAULT_SCALE),
            args.parse_or("seed", 42),
            None,
        )?;
        let (m, n, l, nnz, spa, spy) = ds.stats();
        println!("{name}: m={m} n={n} L={l} |A|={nnz} sp(A)={spa:.4} sp(Y)={spy:.4}");
    }
    Ok(())
}

fn cmd_selftest(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{PinvJob, PipelineCoordinator};
    let coord = PipelineCoordinator::new();
    let scale = args.parse_or("scale", 0.05);
    for method in Method::PAPER_SET {
        let job = PinvJob { method, alpha: 0.3, k: 0.01, seed: 1 };
        let r = coord.run_on_dataset("bibtex", scale, &job)?;
        println!("{:<9} rank={} secs={:.3}", r.method, r.rank, r.svd_secs);
    }
    // artifact runtime smoke
    match crate::runtime::global_executor() {
        Some(_) => {
            let d = crate::runtime::GemmDispatcher::new(crate::runtime::ExecMode::ArtifactOnly);
            let mut rng = crate::util::rng::Rng::seed_from_u64(0);
            let a = crate::dense::Matrix::randn(100, 100, &mut rng);
            let b = crate::dense::Matrix::randn(100, 100, &mut rng);
            let c1 = d.matmul(&a, &b);
            let c2 = crate::dense::matmul(&a, &b);
            println!("artifact gemm max diff vs native: {:.2e}", c1.max_abs_diff(&c2));
        }
        None => println!("artifacts not built — runtime path skipped (run `make artifacts`)"),
    }
    println!("selftest OK");
    Ok(())
}
