//! CLI dispatch — the leader entrypoint. One subcommand per experiment
//! (DESIGN.md §6) plus operational commands.

use crate::harness::{self, ablate, figures, scaling, sweep, table3};
use crate::pinv::Method;
use crate::util::args::Args;
use crate::util::bench::Reporter;

const USAGE: &str = "\
fastpi — Fast PseudoInverse (Jung & Sael, 2020) reproduction

USAGE: fastpi <command> [options]

EXPERIMENTS (paper artifact regenerators):
  table3     dataset statistics (Table 3)
  fig1       degree distributions (Figure 1)
  fig3       reordering progress + spy plot (Figure 3)
  fig4       reconstruction error sweep (Figure 4)
  fig5       multi-label P@k sweep (Figure 5)
  fig6       running-time sweep (Figure 6)
  scaling    empirical complexity fits (Table 2 / Lemma 1)
  ablate     design-choice ablations

OPERATIONS:
  pinv       compute a pseudoinverse on a dataset and report stages
  train      fit a model and publish it to a versioned model store
  serve      start the scoring server (--model-dir serves the store's
             latest version instead of retraining)
  update     fold new rows into the stored model (paper Eq. 2) and
             publish a new version; reports incremental-vs-recompute time
  lifecycle-check  headless train->serve->LEARN->RELOAD smoke (CI)
  datagen    generate + cache a dataset, print stats
  selftest   quick end-to-end smoke test

COMMON OPTIONS:
  --datasets a,b     datasets (default amazon,rcv,eurlex,bibtex)
  --dataset name     single dataset (fig1/fig3/pinv/train/serve)
  --alphas 0.1,0.5   target rank ratios
  --alpha 0.3        single ratio
  --scale 0.1        dataset scale factor (1.0 = full Table 3 size)
  --methods a,b      fastpi,randpi,krylovpi,frpca,dense
  --seed 42          RNG seed
  --threads N        worker threads

LIFECYCLE OPTIONS:
  --model-dir DIR      model store (default target/models/<dataset>)
  --holdout 0.2        train: fraction of rows held out for updates
  --batch 64           update: held-out rows to fold per invocation
  --rows A.mtx         update: fold rows from a MatrixMarket file instead
  --labels Y.mtx       update: label rows matching --rows
  --learn-batch 1      serve: LEARN examples buffered per fold
  --resolve-rows N     flag a full re-solve after N folded rows (0=never)
  --resolve-drift 0.05 flag a full re-solve past accumulated drift
  --gc N               update: keep only the newest N store versions
";

pub fn main() {
    let args = Args::from_env();
    if let Some(t) = args.get("threads") {
        if let Ok(n) = t.parse::<usize>() {
            // fix the shared worker pool's width before the first parallel
            // region spins it up (first configuration wins)
            crate::runtime::pool::configure_threads(n);
        }
    }
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "table3" => cmd_table3(&args),
        "fig1" => cmd_fig1(&args),
        "fig3" => cmd_fig3(&args),
        "fig4" => cmd_sweep(&args, SweepKind::Fig4),
        "fig5" => cmd_sweep(&args, SweepKind::Fig5),
        "fig6" => cmd_sweep(&args, SweepKind::Fig6),
        "scaling" => cmd_scaling(&args),
        "ablate" => cmd_ablate(&args),
        "pinv" => cmd_pinv(&args),
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "update" => cmd_update(&args),
        "lifecycle-check" => cmd_lifecycle_check(&args),
        "datagen" => cmd_datagen(&args),
        "selftest" => cmd_selftest(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn datasets_arg(args: &Args) -> Vec<String> {
    args.parse_list(
        "datasets",
        &harness::DEFAULT_DATASETS.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
    )
}

fn methods_arg(args: &Args) -> Vec<Method> {
    match args.get("methods") {
        Some(spec) => spec
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                Method::from_name(s).unwrap_or_else(|| {
                    eprintln!("unknown method `{s}`");
                    std::process::exit(2)
                })
            })
            .collect(),
        None => Method::PAPER_SET.to_vec(),
    }
}

fn cmd_table3(args: &Args) -> crate::error::Result<()> {
    let rows = table3::table3(
        &datasets_arg(args),
        args.parse_or("scale", harness::DEFAULT_SCALE),
        args.parse_or("seed", 42),
    )?;
    print!("{}", table3::render(&rows));
    Ok(())
}

fn cmd_fig1(args: &Args) -> crate::error::Result<()> {
    for ds in resolve_single_or_all(args) {
        let f = figures::fig1(&ds, args.parse_or("scale", harness::DEFAULT_SCALE), args.parse_or("seed", 42))?;
        print!("{}", figures::render_fig1(&f));
    }
    Ok(())
}

fn cmd_fig3(args: &Args) -> crate::error::Result<()> {
    for ds in resolve_single_or_all(args) {
        let f = figures::fig3(&ds, args.parse_or("scale", harness::DEFAULT_SCALE), args.parse_or("seed", 42))?;
        print!("{}", figures::render_fig3(&f));
    }
    Ok(())
}

fn resolve_single_or_all(args: &Args) -> Vec<String> {
    match args.get("dataset") {
        Some(d) => vec![d.to_string()],
        None => datasets_arg(args),
    }
}

enum SweepKind {
    Fig4,
    Fig5,
    Fig6,
}

fn cmd_sweep(args: &Args, kind: SweepKind) -> crate::error::Result<()> {
    let cfg = sweep::SweepConfig {
        datasets: datasets_arg(args),
        alphas: args.parse_list("alphas", &harness::DEFAULT_ALPHAS),
        methods: methods_arg(args),
        scale: args.parse_or("scale", harness::DEFAULT_SCALE),
        seed: args.parse_or("seed", 42),
        reconstruction: matches!(kind, SweepKind::Fig4),
        regression: matches!(kind, SweepKind::Fig5),
    };
    let name = match kind {
        SweepKind::Fig4 => "fig4_reconstruction",
        SweepKind::Fig5 => "fig5_accuracy",
        SweepKind::Fig6 => "fig6_runtime",
    };
    let mut rep = Reporter::new(name);
    sweep::run_sweep(&cfg, |r| {
        let mut vals: Vec<(&str, f64)> = vec![("secs", r.svd_secs), ("rank", r.rank as f64)];
        if let Some(e) = r.recon_error {
            vals.push(("recon_err", e));
        }
        if let Some(p) = r.p_at_1 {
            vals.push(("p@1", p));
        }
        if let Some(p) = r.p_at_3 {
            vals.push(("p@3", p));
        }
        if let Some(p) = r.p_at_5 {
            vals.push(("p@5", p));
        }
        rep.add(
            &[
                ("dataset", r.dataset.clone()),
                ("method", r.method.to_string()),
                ("alpha", format!("{}", r.alpha)),
            ],
            &vals,
        );
    })?;
    rep.finish();
    Ok(())
}

fn cmd_scaling(args: &Args) -> crate::error::Result<()> {
    let seed = args.parse_or("seed", 42);
    let ms = args.parse_list("ms", &[500usize, 1000, 2000, 4000]);
    let pm = scaling::sweep_m(&ms, 200, 0.3, seed)?;
    let mut rep = Reporter::new("table2_scaling");
    for p in &pm {
        rep.add(&[("axis", p.axis.into()), ("value", p.value.to_string())], &[("secs", p.secs)]);
    }
    println!("slope time~m^a: a = {:.2} (Lemma 1 predicts ≈1)", scaling::loglog_slope(&pm));
    let alphas = args.parse_list("alphas", &[0.1, 0.2, 0.4, 0.8]);
    let pa = scaling::sweep_alpha(&alphas, 2000, 400, seed)?;
    for p in &pa {
        rep.add(&[("axis", p.axis.into()), ("value", p.value.to_string())], &[("secs", p.secs)]);
    }
    println!("slope time~r^b: b = {:.2} (Lemma 1 predicts ≈2)", scaling::loglog_slope(&pa));
    rep.finish();
    Ok(())
}

fn cmd_ablate(args: &Args) -> crate::error::Result<()> {
    let scale = args.parse_or("scale", harness::DEFAULT_SCALE);
    let seed = args.parse_or("seed", 42);
    let alpha = args.parse_or("alpha", 0.3);
    let ds = args.str_or("dataset", "bibtex");
    let mut rep = Reporter::new("ablation");

    let (fs, ss, fe, se) = ablate::ablate_reorder(&ds, scale, alpha, seed)?;
    rep.add(&[("ablation", "reorder_on".into())], &[("secs", fs), ("err", fe)]);
    rep.add(&[("ablation", "reorder_off".into())], &[("secs", ss), ("err", se)]);

    let (bs, ms, be, me) = ablate::ablate_block_svd(&ds, scale, alpha, seed)?;
    rep.add(&[("ablation", "block_svd".into())], &[("secs", bs), ("err", be)]);
    rep.add(&[("ablation", "monolithic_a11".into())], &[("secs", ms), ("err", me)]);

    for (k, secs, m2, n2, blocks, iters) in
        ablate::ablate_hub_ratio(&ds, scale, alpha, &[0.005, 0.01, 0.02, 0.05, 0.1], seed)?
    {
        rep.add(
            &[("ablation", format!("hub_k={k}"))],
            &[
                ("secs", secs),
                ("m2", m2 as f64),
                ("n2", n2 as f64),
                ("blocks", blocks as f64),
                ("iters", iters as f64),
            ],
        );
    }

    for (name, secs, err) in ablate::ablate_inner_engine(&ds, scale, alpha, seed)? {
        rep.add(&[("ablation", format!("inner_{name}"))], &[("secs", secs), ("err", err)]);
    }
    rep.finish();
    Ok(())
}

fn cmd_pinv(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{PinvJob, PipelineCoordinator};
    let ds = args.str_or("dataset", "bibtex");
    let method = Method::from_name(&args.str_or("method", "fastpi"))
        .unwrap_or(Method::FastPi);
    let job = PinvJob {
        method,
        alpha: args.parse_or("alpha", 0.3),
        k: args.parse_or("k", 0.01),
        seed: args.parse_or("seed", 42),
    };
    let coord = PipelineCoordinator::new();
    let report =
        coord.run_on_dataset(&ds, args.parse_or("scale", harness::DEFAULT_SCALE), &job)?;
    println!(
        "{} on {ds}: rank={} secs={:.3}\nstages:\n{}",
        report.method,
        report.rank,
        report.svd_secs,
        report.stages.render()
    );
    Ok(())
}

/// Resolve the model store directory: `--model-dir` or the per-dataset
/// default.
fn model_dir_arg(args: &Args, dataset: &str) -> std::path::PathBuf {
    match args.get("model-dir") {
        Some(d) => d.into(),
        None => format!("target/models/{dataset}").into(),
    }
}

fn updater_cfg_arg(args: &Args) -> crate::model::UpdaterConfig {
    crate::model::UpdaterConfig {
        learn_batch: args.parse_or("learn-batch", 1usize),
        resolve_rows: args.parse_or("resolve-rows", 0usize),
        resolve_drift: args.parse_or("resolve-drift", 0.05),
        ..Default::default()
    }
}

fn cmd_train(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{PinvJob, PipelineCoordinator};
    use crate::data::load_dataset;
    use crate::model::ModelStore;
    let name = args.str_or("dataset", "bibtex");
    let scale = args.parse_or("scale", harness::DEFAULT_SCALE);
    let seed = args.parse_or("seed", 42);
    let holdout: f64 = args.parse_or("holdout", 0.2);
    let ds = load_dataset(&name, scale, seed, None)?;
    let job = PinvJob { method: Method::FastPi, alpha: args.parse_or("alpha", 0.5), k: ds.k, seed };
    let total = ds.a.rows();
    let train_rows =
        ((total as f64) * (1.0 - holdout.clamp(0.0, 0.95))).ceil().max(1.0) as usize;
    println!(
        "training on {name} (scale {scale}): {train_rows}/{total} rows, {} held out for updates",
        total - train_rows.min(total)
    );
    let t = std::time::Instant::now();
    let (artifact, report) = PipelineCoordinator::new().train_model(&ds, &job, train_rows)?;
    let store = ModelStore::open(&model_dir_arg(args, &name))?;
    let version = store.publish(&artifact)?;
    println!(
        "published v{version} to {} (rank={} train_secs={:.3})",
        store.dir().display(),
        report.rank,
        t.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{PinvJob, PipelineCoordinator, ScoreServer, ServerConfig};
    use crate::data::load_dataset;
    use crate::model::{ModelStore, OnlineUpdater};
    let server_cfg = ServerConfig {
        threads: args.parse_or("threads", 0usize),
        ..Default::default()
    };
    let server = if let Some(dir) = args.get("model-dir") {
        // lifecycle path: serve the store's latest version, no retraining
        let store = ModelStore::open(std::path::Path::new(dir))?;
        let Some((version, artifact)) = store.load_latest()? else {
            return Err(crate::error::Error::Invalid(format!(
                "no model versions in {dir} — run `fastpi train --model-dir {dir}` first"
            )));
        };
        let (m, n, l) = artifact.shape();
        println!(
            "serving v{version} from {dir}: {} rows folded, rank={}, {n} features, {l} labels",
            m,
            artifact.rank()
        );
        let updater = OnlineUpdater::new(artifact, updater_cfg_arg(args));
        ScoreServer::start_lifecycle(updater, Some(store), version, server_cfg)
            .map_err(crate::error::Error::Io)?
    } else {
        // no store: train in-process and serve with an in-memory lifecycle
        let name = args.str_or("dataset", "bibtex");
        let scale = args.parse_or("scale", harness::DEFAULT_SCALE);
        let seed = args.parse_or("seed", 42);
        let ds = load_dataset(&name, scale, seed, None)?;
        let job =
            PinvJob { method: Method::FastPi, alpha: args.parse_or("alpha", 0.5), k: ds.k, seed };
        println!("computing pseudoinverse for {name} (scale {scale})...");
        let rows = ds.a.rows();
        let (artifact, _) = PipelineCoordinator::new().train_model(&ds, &job, rows)?;
        let updater = OnlineUpdater::new(artifact, updater_cfg_arg(args));
        ScoreServer::start_lifecycle(updater, None, 0, server_cfg)
            .map_err(crate::error::Error::Io)?
    };
    println!(
        "scoring server on {} — verbs: SCORE <topk> j:v,... | LEARN <labels|-> j:v,... | VERSION | RELOAD | STATS  (Ctrl-C to stop)",
        server.addr
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_update(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{PinvJob, PipelineCoordinator};
    use crate::data::load_dataset;
    use crate::model::{ModelStore, OnlineUpdater};
    use crate::sparse::{io as sio, Csr};
    let dir = model_dir_arg(args, &args.str_or("dataset", "bibtex"));
    let store = ModelStore::open(&dir)?;
    let Some((version, artifact)) = store.load_latest()? else {
        return Err(crate::error::Error::Invalid(format!(
            "no model versions in {} — run `fastpi train` first",
            dir.display()
        )));
    };
    let meta = artifact.meta.clone();
    let (_, _, l) = artifact.shape();
    let mut updater = OnlineUpdater::new(artifact, updater_cfg_arg(args));

    // new rows: an explicit MatrixMarket file (folds without moving the
    // dataset cursor), or the dataset's held-out stream starting at the
    // stored cursor (dataset is loaded once and reused for the recompute
    // comparison below)
    let mut loaded_ds = None;
    let rep = if let Some(rows_path) = args.get("rows") {
        let a = sio::read_matrix_market(std::path::Path::new(rows_path))?;
        let y = match args.get("labels") {
            Some(p) => sio::read_matrix_market(std::path::Path::new(p))?,
            None => Csr::zeros(a.rows(), l),
        };
        updater.apply_block(&a, &y)?
    } else {
        if meta.dataset.is_empty() {
            return Err(crate::error::Error::Invalid(
                "model has no dataset identity — pass --rows/--labels files".into(),
            ));
        }
        let ds = loaded_ds.insert(load_dataset(&meta.dataset, meta.scale, meta.seed, None)?);
        let start = meta.dataset_rows as usize;
        if start >= ds.a.rows() {
            println!(
                "v{version}: all {} rows of {} already folded — nothing to update",
                ds.a.rows(),
                meta.dataset
            );
            return Ok(());
        }
        let take = args.parse_or("batch", 64usize).min(ds.a.rows() - start);
        let a_new = ds.a.block(start, 0, take, ds.a.cols());
        let y_new = ds.y.block(start, 0, take, ds.y.cols());
        updater.apply_dataset_block(&a_new, &y_new)?
    };
    let new_version = store.publish(updater.artifact())?;
    println!(
        "v{version} -> v{new_version}: folded {} rows in {:.3}s (rank={} drift={:.3e} total_drift={:.3e})",
        rep.rows, rep.secs, rep.rank, rep.drift_inc, rep.drift_total
    );

    // the paper's speed claim as a serving-lifecycle metric: the same rows
    // via a full FastPI recompute on the accumulated dataset prefix
    if let (Some(ds), false) = (&loaded_ds, args.flag("no-compare")) {
        let new_meta = &updater.artifact().meta;
        let upto = (new_meta.dataset_rows as usize).min(ds.a.rows());
        let job = PinvJob { method: Method::FastPi, alpha: meta.alpha, k: meta.k, seed: meta.seed };
        let t = std::time::Instant::now();
        let (resolved, _) = PipelineCoordinator::new().train_model(ds, &job, upto)?;
        let recompute_secs = t.elapsed().as_secs_f64();
        println!(
            "incremental={:.3}s full-recompute={:.3}s speedup={:.1}x",
            rep.secs,
            recompute_secs,
            recompute_secs / rep.secs.max(1e-9)
        );
        if rep.needs_resolve || args.flag("resolve") {
            if new_meta.rows_trained > new_meta.dataset_rows {
                println!(
                    "note: re-solve covers the {upto}-row dataset prefix; {} ad-hoc learned rows are not in it",
                    new_meta.rows_trained - new_meta.dataset_rows
                );
            }
            let rv = store.publish(&resolved)?;
            println!(
                "re-solve threshold crossed — published full re-solve as v{rv} (drift reset)"
            );
        }
    } else if rep.needs_resolve {
        println!(
            "re-solve threshold crossed (drift={:.3e}, rows_since_solve={}) — retrain with `fastpi train`",
            rep.drift_total,
            updater.artifact().meta.rows_since_solve
        );
    }
    if let Some(keep) = args.get("gc") {
        // deleting versions on a malformed argument would be destructive
        let keep: usize = keep.parse().map_err(|_| {
            crate::error::Error::Invalid(format!("bad --gc value `{keep}` (want a count)"))
        })?;
        let removed = store.gc(keep)?;
        println!("gc: removed {removed} old versions (kept newest {keep})");
    }
    Ok(())
}

/// Headless end-to-end smoke of the model lifecycle: serve the store's
/// latest version and drive SCORE/LEARN/RELOAD/VERSION/STATS over TCP,
/// asserting the save→load→update→swap loop behaves. Exits non-zero on any
/// mismatch, so CI can gate on it after a separate `train` process — the
/// restart between the two is the point.
fn cmd_lifecycle_check(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{text_request, ScoreServer, ServerConfig};
    use crate::error::Error;
    use crate::model::{ModelStore, OnlineUpdater};
    let dir = model_dir_arg(args, &args.str_or("dataset", "bibtex"));
    let store = ModelStore::open(&dir)?;
    let Some((version, artifact)) = store.load_latest()? else {
        return Err(Error::Invalid(format!(
            "no model versions in {} — run `fastpi train` first",
            dir.display()
        )));
    };
    let (_, n, _) = artifact.shape();
    let updater = OnlineUpdater::new(artifact, updater_cfg_arg(args));
    let server = ScoreServer::start_lifecycle(updater, Some(store), version, ServerConfig::default())
        .map_err(Error::Io)?;
    let addr = server.addr;

    let check = |what: &str, got: &str, want_prefix: &str| -> crate::error::Result<()> {
        if got.starts_with(want_prefix) {
            println!("  {what}: {got}");
            Ok(())
        } else {
            Err(Error::Invalid(format!("{what}: expected `{want_prefix}...`, got `{got}`")))
        }
    };
    let req = |line: &str| text_request(addr, line).map_err(Error::Io);

    check("PING", &req("PING")?, "PONG")?;
    check("VERSION", &req("VERSION")?, &format!("VERSION id={version} "))?;
    let feats = format!("0:1.0,{}:0.5", n.saturating_sub(1));
    let score1 = req(&format!("SCORE 3 {feats}"))?;
    check("SCORE", &score1, "OK ")?;
    check("RELOAD", &req("RELOAD")?, &format!("OK version={version}"))?;
    let score2 = req(&format!("SCORE 3 {feats}"))?;
    if score1 != score2 {
        return Err(Error::Invalid(format!(
            "SCORE changed across RELOAD of the same version: `{score1}` vs `{score2}`"
        )));
    }
    println!("  SCORE after RELOAD: identical reply");
    check("LEARN", &req(&format!("LEARN 0 {feats}"))?, "OK version=")?;
    // learn_batch defaults to 1, so the fold + hot swap already happened
    check("VERSION after LEARN", &req("VERSION")?, &format!("VERSION id={} ", version + 1))?;
    let score3 = req(&format!("SCORE 3 {feats}"))?;
    check("SCORE after swap", &score3, "OK ")?;
    let stats = req("STATS")?;
    check("STATS", &stats, "STATS served=")?;
    for field in ["rejected=", "queue_depth=", "swaps=", "learned="] {
        if !stats.contains(field) {
            return Err(Error::Invalid(format!("STATS missing `{field}`: {stats}")));
        }
    }
    server.shutdown();
    println!("lifecycle-check OK: v{version} served, reloaded, learned into v{}", version + 1);
    Ok(())
}

fn cmd_datagen(args: &Args) -> crate::error::Result<()> {
    use crate::data::load_dataset;
    for name in datasets_arg(args) {
        let ds = load_dataset(
            &name,
            args.parse_or("scale", harness::DEFAULT_SCALE),
            args.parse_or("seed", 42),
            None,
        )?;
        let (m, n, l, nnz, spa, spy) = ds.stats();
        println!("{name}: m={m} n={n} L={l} |A|={nnz} sp(A)={spa:.4} sp(Y)={spy:.4}");
    }
    Ok(())
}

fn cmd_selftest(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{PinvJob, PipelineCoordinator};
    let coord = PipelineCoordinator::new();
    let scale = args.parse_or("scale", 0.05);
    for method in Method::PAPER_SET {
        let job = PinvJob { method, alpha: 0.3, k: 0.01, seed: 1 };
        let r = coord.run_on_dataset("bibtex", scale, &job)?;
        println!("{:<9} rank={} secs={:.3}", r.method, r.rank, r.svd_secs);
    }
    // artifact runtime smoke
    match crate::runtime::global_executor() {
        Some(_) => {
            let d = crate::runtime::GemmDispatcher::new(crate::runtime::ExecMode::ArtifactOnly);
            let mut rng = crate::util::rng::Rng::seed_from_u64(0);
            let a = crate::dense::Matrix::randn(100, 100, &mut rng);
            let b = crate::dense::Matrix::randn(100, 100, &mut rng);
            let c1 = d.matmul(&a, &b);
            let c2 = crate::dense::matmul(&a, &b);
            println!("artifact gemm max diff vs native: {:.2e}", c1.max_abs_diff(&c2));
        }
        None => println!("artifacts not built — runtime path skipped (run `make artifacts`)"),
    }
    println!("selftest OK");
    Ok(())
}
