//! CLI dispatch — the leader entrypoint. One subcommand per experiment
//! (DESIGN.md §6) plus operational commands.

use crate::harness::{self, ablate, figures, scaling, sweep, table3};
use crate::pinv::Method;
use crate::util::args::Args;
use crate::util::bench::Reporter;

const USAGE: &str = "\
fastpi — Fast PseudoInverse (Jung & Sael, 2020) reproduction

USAGE: fastpi <command> [options]

EXPERIMENTS (paper artifact regenerators):
  table3     dataset statistics (Table 3)
  fig1       degree distributions (Figure 1)
  fig3       reordering progress + spy plot (Figure 3)
  fig4       reconstruction error sweep (Figure 4)
  fig5       multi-label P@k sweep (Figure 5)
  fig6       running-time sweep (Figure 6)
  scaling    empirical complexity fits (Table 2 / Lemma 1)
  ablate     design-choice ablations

OPERATIONS:
  pinv       compute a pseudoinverse on a dataset and report stages
  train      fit a model and publish it to a versioned model store
  serve      start the scoring server (--model-dir serves the store's
             latest version instead of retraining; --replica-of ADDR
             follows a primary as a read-only snapshot-shipped replica)
  update     fold new rows into the stored model (paper Eq. 2) and
             publish a new version; reports incremental-vs-recompute time
  ship       pull the latest FPIM snapshot from a serving primary into a
             local store (one-shot, or --watch to keep polling)
  promote    promote a follower replica to primary: `fastpi promote ADDR`
             stops its sync loop, verifies its latest local version, bumps
             the store's promotion epoch (fencing the old primary's stale
             publishes out of the lineage), and enables LEARN/RELOAD
  shard      split the store's latest model into a label-space shard set
             and publish it (one atomic shard-set version) to --out
  reshard    live N->M resharding: `fastpi reshard ADDR --shards M`
             sends RESHARD to a serving node, which reassembles its
             store's latest version bitwise and publishes one atomic
             M-way shard-set version; `fastpi reshard ADDR --groups
             a+b,c,d` flips a sharded router's fan-out map epoch-style
             onto a new fleet (probed live, right slices, lockstep
             versions before the swap — refused otherwise)
  route      front-end router fanning SCORE across replicas; STATS
             reports per-replica versions + skew. --sharded switches to
             scatter-gather over shard groups (SCORE merged bitwise,
             LEARN broadcast with unanimous version advance)
  lifecycle-check  headless train->serve->LEARN->RELOAD smoke (CI)
  cluster-check    headless replica fan-out check: primary + N follower
             processes + router, propagation asserted end to end (CI)
  shard-check      headless sharding check: split a trained model into N
             shards, serve each as its own OS process, scatter-gather
             route, and assert bitwise-identical replies vs the
             unsharded model plus unanimous LEARN advance (CI)
  failover-check   headless resilience check: sharded replica chains
             (per-shard primary + follower processes) behind the router;
             kill one member per group mid-load, then promote the dead
             primary's follower — asserts zero dropped requests, bitwise
             SCORE vs an unsharded reference, LEARN restored, skew 0 (CI)
  reshard-check    headless elastic-fleet check: 3-shard fleet under
             concurrent load is live-resharded to 4 — atomic store
             publish via the serve RESHARD verb, new shard processes,
             one router map flip — asserting zero dropped requests,
             bitwise SCORE vs the unsharded reference throughout, and
             both reshard surfaces journaled (CI)
  metrics    dump a server or router METRICS snapshot: `fastpi metrics
             HOST:PORT` (routers answer with the fleet-merged view)
  events     drain a server or router EVENTS journal: `fastpi events
             HOST:PORT [N]` (N = max events, default all)
  bench-diff perf-trajectory gate: diff target/bench_results/BENCH_*.json
             against the committed bench_baselines/ snapshot
  analyze    in-tree static analysis: determinism + liveness invariant
             lints (float-cmp-unwrap, panic-in-server, lock-order,
             nondet-kernel, stats-key-drift) over rust/src, rust/tests,
             benches, examples — or explicit PATHS. Nonzero exit on any
             unsuppressed finding (CI gate). --list emits one
             machine-readable `path:line:col lint message` per finding;
             --fix-list appends the suggested fix
  datagen    generate + cache a dataset, print stats
  selftest   quick end-to-end smoke test

COMMON OPTIONS:
  --datasets a,b     datasets (default amazon,rcv,eurlex,bibtex)
  --dataset name     single dataset (fig1/fig3/pinv/train/serve)
  --alphas 0.1,0.5   target rank ratios
  --alpha 0.3        single ratio
  --scale 0.1        dataset scale factor (1.0 = full Table 3 size)
  --methods a,b      fastpi,randpi,krylovpi,frpca,dense
  --seed 42          RNG seed
  --threads N        worker threads

LIFECYCLE OPTIONS:
  --model-dir DIR      model store (default target/models/<dataset>)
  --holdout 0.2        train: fraction of rows held out for updates
  --batch 64           update: held-out rows to fold per invocation
  --rows A.mtx         update: fold rows from a MatrixMarket file instead
  --labels Y.mtx       update: label rows matching --rows
  --learn-batch 1      serve: LEARN examples buffered per fold
  --resolve-rows N     flag a full re-solve after N folded rows (0=never)
  --resolve-drift 0.05 flag a full re-solve past accumulated drift
  --fold-mode exact    serve/update: row-fold basis policy. `project`
                       freezes the factors (C/Z-only folds onto the
                       frozen basis) so consecutive versions stay
                       factor-stable and replica SHIP deltas fire;
                       `exact` rotates the basis every fold (paper
                       Eq. 2) and replicas fall back to full snapshots
  --gc N               update: keep only the newest N store versions

SERVING OPTIONS:
  --slo-ms N           serve: per-batch latency budget in ms. The batcher
                       sizes drains from its measured per-batch-size cost
                       table to stay inside the budget (falling back to
                       max_batch until it has observations), and the
                       client reply deadline derives from it (`ERR
                       deadline` on expiry). 0/absent = fixed max_batch
  --shed-depth N       serve: admission control — refuse new SCOREs fast
                       with `ERR busy` once the request queue holds N
                       entries (0 = accept until hard-full). Shed
                       requests count under STATS shed=

  A primary with --model-dir also serves every models/<name> namespace
  in the store as a named model: `MODEL <name> SCORE ...` scores it,
  `MODEL <name> VERSION` reports its shape (publish into a namespace
  with the store API; the bare verbs keep addressing the primary model)

REPLICATION OPTIONS:
  --replica-of ADDR    serve: follow this primary (requires --model-dir,
                       the replica's own local store directory; the
                       lifecycle flags --learn-batch/--resolve-* set the
                       config a later `promote` installs — keep them
                       identical across a shard group's members)
  --from ADDR          ship: the serving primary to pull from
  --watch              ship: keep polling instead of one-shot
  --poll-ms 200        replica/ship poll interval
  --replicas a,b,c     route: replica addresses   (cluster-check: count)
  --bind 0.0.0.0:7070  serve/route: listen address (default loopback,
                       ephemeral port)

SHARDING OPTIONS:
  --shards N           shard/shard-check: how many label-space slices
  --out DIR            shard: destination store (default <model-dir>-shards)
  --shard K/N          serve: hold only shard K of an N-shard set (with
                       --model-dir: serve+LEARN that slice; with
                       --replica-of: sync only that slice)
  --sharded            route: scatter-gather mode; --replicas lists one
                       group per shard IN SHARD ORDER, '+' joining the
                       interchangeable members of a group (a0+a1,b,c)

BENCH-DIFF OPTIONS:
  --baseline DIR       committed snapshot (default bench_baselines)
  --current DIR        fresh results (default target/bench_results)
  --max-regress 0.2    allowed fractional regression per gated key
  --keys a,b           gated value keys (default throughput_rps,p50_ms,
                       p95_ms,p99_ms,p99_storm_ms,propagation_p95_ms,
                       delta_ratio,speedup_x,gflops_1t)
";

pub fn main() {
    let args = Args::from_env();
    if let Some(t) = args.get("threads") {
        if let Ok(n) = t.parse::<usize>() {
            // fix the shared worker pool's width before the first parallel
            // region spins it up (first configuration wins)
            crate::runtime::pool::configure_threads(n);
        }
    }
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "table3" => cmd_table3(&args),
        "fig1" => cmd_fig1(&args),
        "fig3" => cmd_fig3(&args),
        "fig4" => cmd_sweep(&args, SweepKind::Fig4),
        "fig5" => cmd_sweep(&args, SweepKind::Fig5),
        "fig6" => cmd_sweep(&args, SweepKind::Fig6),
        "scaling" => cmd_scaling(&args),
        "ablate" => cmd_ablate(&args),
        "pinv" => cmd_pinv(&args),
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "update" => cmd_update(&args),
        "ship" => cmd_ship(&args),
        "promote" => cmd_promote(&args),
        "shard" => cmd_shard(&args),
        "reshard" => cmd_reshard(&args),
        "route" => cmd_route(&args),
        "metrics" => cmd_metrics(&args),
        "events" => cmd_events(&args),
        "lifecycle-check" => cmd_lifecycle_check(&args),
        "cluster-check" => cmd_cluster_check(&args),
        "shard-check" => cmd_shard_check(&args),
        "failover-check" => cmd_failover_check(&args),
        "reshard-check" => cmd_reshard_check(&args),
        "bench-diff" => cmd_bench_diff(&args),
        "analyze" => cmd_analyze(&args),
        "datagen" => cmd_datagen(&args),
        "selftest" => cmd_selftest(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn datasets_arg(args: &Args) -> Vec<String> {
    args.parse_list(
        "datasets",
        &harness::DEFAULT_DATASETS.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
    )
}

fn methods_arg(args: &Args) -> Vec<Method> {
    match args.get("methods") {
        Some(spec) => spec
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                Method::from_name(s).unwrap_or_else(|| {
                    eprintln!("unknown method `{s}`");
                    std::process::exit(2)
                })
            })
            .collect(),
        None => Method::PAPER_SET.to_vec(),
    }
}

fn cmd_table3(args: &Args) -> crate::error::Result<()> {
    let rows = table3::table3(
        &datasets_arg(args),
        args.parse_or("scale", harness::DEFAULT_SCALE),
        args.parse_or("seed", 42),
    )?;
    print!("{}", table3::render(&rows));
    Ok(())
}

fn cmd_fig1(args: &Args) -> crate::error::Result<()> {
    for ds in resolve_single_or_all(args) {
        let f = figures::fig1(&ds, args.parse_or("scale", harness::DEFAULT_SCALE), args.parse_or("seed", 42))?;
        print!("{}", figures::render_fig1(&f));
    }
    Ok(())
}

fn cmd_fig3(args: &Args) -> crate::error::Result<()> {
    for ds in resolve_single_or_all(args) {
        let f = figures::fig3(&ds, args.parse_or("scale", harness::DEFAULT_SCALE), args.parse_or("seed", 42))?;
        print!("{}", figures::render_fig3(&f));
    }
    Ok(())
}

fn resolve_single_or_all(args: &Args) -> Vec<String> {
    match args.get("dataset") {
        Some(d) => vec![d.to_string()],
        None => datasets_arg(args),
    }
}

enum SweepKind {
    Fig4,
    Fig5,
    Fig6,
}

fn cmd_sweep(args: &Args, kind: SweepKind) -> crate::error::Result<()> {
    let cfg = sweep::SweepConfig {
        datasets: datasets_arg(args),
        alphas: args.parse_list("alphas", &harness::DEFAULT_ALPHAS),
        methods: methods_arg(args),
        scale: args.parse_or("scale", harness::DEFAULT_SCALE),
        seed: args.parse_or("seed", 42),
        reconstruction: matches!(kind, SweepKind::Fig4),
        regression: matches!(kind, SweepKind::Fig5),
    };
    let name = match kind {
        SweepKind::Fig4 => "fig4_reconstruction",
        SweepKind::Fig5 => "fig5_accuracy",
        SweepKind::Fig6 => "fig6_runtime",
    };
    let mut rep = Reporter::new(name);
    sweep::run_sweep(&cfg, |r| {
        let mut vals: Vec<(&str, f64)> = vec![("secs", r.svd_secs), ("rank", r.rank as f64)];
        if let Some(e) = r.recon_error {
            vals.push(("recon_err", e));
        }
        if let Some(p) = r.p_at_1 {
            vals.push(("p@1", p));
        }
        if let Some(p) = r.p_at_3 {
            vals.push(("p@3", p));
        }
        if let Some(p) = r.p_at_5 {
            vals.push(("p@5", p));
        }
        rep.add(
            &[
                ("dataset", r.dataset.clone()),
                ("method", r.method.to_string()),
                ("alpha", format!("{}", r.alpha)),
            ],
            &vals,
        );
    })?;
    rep.finish();
    Ok(())
}

fn cmd_scaling(args: &Args) -> crate::error::Result<()> {
    let seed = args.parse_or("seed", 42);
    let ms = args.parse_list("ms", &[500usize, 1000, 2000, 4000]);
    let pm = scaling::sweep_m(&ms, 200, 0.3, seed)?;
    let mut rep = Reporter::new("table2_scaling");
    for p in &pm {
        rep.add(&[("axis", p.axis.into()), ("value", p.value.to_string())], &[("secs", p.secs)]);
    }
    println!("slope time~m^a: a = {:.2} (Lemma 1 predicts ≈1)", scaling::loglog_slope(&pm));
    let alphas = args.parse_list("alphas", &[0.1, 0.2, 0.4, 0.8]);
    let pa = scaling::sweep_alpha(&alphas, 2000, 400, seed)?;
    for p in &pa {
        rep.add(&[("axis", p.axis.into()), ("value", p.value.to_string())], &[("secs", p.secs)]);
    }
    println!("slope time~r^b: b = {:.2} (Lemma 1 predicts ≈2)", scaling::loglog_slope(&pa));
    rep.finish();
    Ok(())
}

fn cmd_ablate(args: &Args) -> crate::error::Result<()> {
    let scale = args.parse_or("scale", harness::DEFAULT_SCALE);
    let seed = args.parse_or("seed", 42);
    let alpha = args.parse_or("alpha", 0.3);
    let ds = args.str_or("dataset", "bibtex");
    let mut rep = Reporter::new("ablation");

    let (fs, ss, fe, se) = ablate::ablate_reorder(&ds, scale, alpha, seed)?;
    rep.add(&[("ablation", "reorder_on".into())], &[("secs", fs), ("err", fe)]);
    rep.add(&[("ablation", "reorder_off".into())], &[("secs", ss), ("err", se)]);

    let (bs, ms, be, me) = ablate::ablate_block_svd(&ds, scale, alpha, seed)?;
    rep.add(&[("ablation", "block_svd".into())], &[("secs", bs), ("err", be)]);
    rep.add(&[("ablation", "monolithic_a11".into())], &[("secs", ms), ("err", me)]);

    for (k, secs, m2, n2, blocks, iters) in
        ablate::ablate_hub_ratio(&ds, scale, alpha, &[0.005, 0.01, 0.02, 0.05, 0.1], seed)?
    {
        rep.add(
            &[("ablation", format!("hub_k={k}"))],
            &[
                ("secs", secs),
                ("m2", m2 as f64),
                ("n2", n2 as f64),
                ("blocks", blocks as f64),
                ("iters", iters as f64),
            ],
        );
    }

    for (name, secs, err) in ablate::ablate_inner_engine(&ds, scale, alpha, seed)? {
        rep.add(&[("ablation", format!("inner_{name}"))], &[("secs", secs), ("err", err)]);
    }
    rep.finish();
    Ok(())
}

fn cmd_pinv(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{PinvJob, PipelineCoordinator};
    let ds = args.str_or("dataset", "bibtex");
    let method = Method::from_name(&args.str_or("method", "fastpi"))
        .unwrap_or(Method::FastPi);
    let job = PinvJob {
        method,
        alpha: args.parse_or("alpha", 0.3),
        k: args.parse_or("k", 0.01),
        seed: args.parse_or("seed", 42),
    };
    let coord = PipelineCoordinator::new();
    let report =
        coord.run_on_dataset(&ds, args.parse_or("scale", harness::DEFAULT_SCALE), &job)?;
    println!(
        "{} on {ds}: rank={} secs={:.3}\nstages:\n{}",
        report.method,
        report.rank,
        report.svd_secs,
        report.stages.render()
    );
    Ok(())
}

/// Resolve the model store directory: `--model-dir` or the per-dataset
/// default.
fn model_dir_arg(args: &Args, dataset: &str) -> std::path::PathBuf {
    match args.get("model-dir") {
        Some(d) => d.into(),
        None => format!("target/models/{dataset}").into(),
    }
}

fn updater_cfg_arg(args: &Args) -> crate::model::UpdaterConfig {
    crate::model::UpdaterConfig {
        learn_batch: args.parse_or("learn-batch", 1usize),
        resolve_rows: args.parse_or("resolve-rows", 0usize),
        resolve_drift: args.parse_or("resolve-drift", 0.05),
        // `--fold-mode project` freezes the factors across row folds
        // (C/Z-only updates), which is what makes SHIP deltas fire
        fold_mode: args
            .get("fold-mode")
            .and_then(|s| crate::model::FoldMode::parse(&s))
            .unwrap_or(crate::model::FoldMode::Exact),
        ..Default::default()
    }
}

fn cmd_train(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{PinvJob, PipelineCoordinator};
    use crate::data::load_dataset;
    use crate::model::ModelStore;
    let name = args.str_or("dataset", "bibtex");
    let scale = args.parse_or("scale", harness::DEFAULT_SCALE);
    let seed = args.parse_or("seed", 42);
    let holdout: f64 = args.parse_or("holdout", 0.2);
    let ds = load_dataset(&name, scale, seed, None)?;
    let job = PinvJob { method: Method::FastPi, alpha: args.parse_or("alpha", 0.5), k: ds.k, seed };
    let total = ds.a.rows();
    let train_rows =
        ((total as f64) * (1.0 - holdout.clamp(0.0, 0.95))).ceil().max(1.0) as usize;
    println!(
        "training on {name} (scale {scale}): {train_rows}/{total} rows, {} held out for updates",
        total - train_rows.min(total)
    );
    let t = std::time::Instant::now();
    let (artifact, report) = PipelineCoordinator::new().train_model(&ds, &job, train_rows)?;
    let store = ModelStore::open(&model_dir_arg(args, &name))?;
    let version = store.publish(&artifact)?;
    println!(
        "published v{version} to {} (rank={} train_secs={:.3})",
        store.dir().display(),
        report.rank,
        t.elapsed().as_secs_f64()
    );
    Ok(())
}

/// Resolve `host:port` (hostnames included) to one socket address.
fn resolve_addr(spec: &str) -> crate::error::Result<std::net::SocketAddr> {
    use std::net::ToSocketAddrs;
    spec.to_socket_addrs()
        .map_err(crate::error::Error::Io)?
        .next()
        .ok_or_else(|| crate::error::Error::Invalid(format!("cannot resolve `{spec}`")))
}

/// Parse the `--shard K/N` option, if present.
fn shard_arg(args: &Args) -> crate::error::Result<Option<(u64, u64)>> {
    match args.get("shard") {
        None => Ok(None),
        Some(spec) => crate::model::parse_shard_spec(spec).map(Some).ok_or_else(|| {
            crate::error::Error::Invalid(format!(
                "bad --shard `{spec}` (want K/N with K < N and N ≥ 2, e.g. 0/3)"
            ))
        }),
    }
}

/// Parse `--slo-ms` into the serving latency budget (0 or absent = no
/// budget: fixed `max_batch` drains and the default reply deadline).
fn slo_arg(args: &Args) -> crate::error::Result<Option<std::time::Duration>> {
    match args.get("slo-ms") {
        None => Ok(None),
        Some(v) => {
            let ms: u64 = v.parse().map_err(|_| {
                crate::error::Error::Invalid(format!("bad --slo-ms `{v}` (want milliseconds)"))
            })?;
            Ok((ms > 0).then(|| std::time::Duration::from_millis(ms)))
        }
    }
}

fn cmd_serve(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{PinvJob, PipelineCoordinator, ReplicaConfig, ScoreServer, ServerConfig};
    use crate::data::load_dataset;
    use crate::model::{ModelStore, OnlineUpdater};
    use crate::regress::MultiLabelModel;
    let mut server_cfg = ServerConfig {
        threads: args.parse_or("threads", 0usize),
        bind: args.str_or("bind", "127.0.0.1:0"),
        slo: slo_arg(args)?,
        shed_depth: args.parse_or("shed-depth", 0usize),
        ..Default::default()
    };
    let shard = shard_arg(args)?;
    let server = if let Some(primary) = args.get("replica-of") {
        // follower replica: read-only, pull-synced from the primary —
        // only its own label-space slice when --shard is given
        let primary = resolve_addr(primary)?;
        let dir = args.get("model-dir").ok_or_else(|| {
            crate::error::Error::Invalid(
                "--replica-of needs --model-dir (the replica's own local store)".into(),
            )
        })?;
        let store = ModelStore::open(std::path::Path::new(dir))?;
        let poll = std::time::Duration::from_millis(args.parse_or("poll-ms", 200u64));
        // the lifecycle knobs ride along so a later PROMOTE installs a
        // fleet-matching updater (learn_batch etc. must equal the
        // siblings' or broadcast-LEARN unanimity breaks post-promotion)
        let rc = ReplicaConfig {
            primary,
            poll,
            shard,
            updater_cfg: updater_cfg_arg(args),
            ..Default::default()
        };
        let server = ScoreServer::start_replica(store, rc, server_cfg)?;
        match shard {
            Some((k, n)) => println!(
                "shard-{k}/{n} replica serving v{} from {dir}, following {primary} (poll {}ms)",
                server.current_version(),
                poll.as_millis()
            ),
            None => println!(
                "replica serving v{} from {dir}, following {primary} (poll {}ms)",
                server.current_version(),
                poll.as_millis()
            ),
        }
        server
    } else if let Some(dir) = args.get("model-dir") {
        // lifecycle path: serve the store's latest version, no retraining;
        // with --shard K/N, serve (and LEARN-advance) only that slice
        let store = ModelStore::open(std::path::Path::new(dir))?;
        let latest = match shard {
            Some((k, n)) => store.load_latest_shard(k, n)?,
            None => store.load_latest()?,
        };
        let Some((version, artifact)) = latest else {
            return Err(crate::error::Error::Invalid(match shard {
                Some((k, n)) => format!(
                    "no shard {k}/{n} versions in {dir} — run `fastpi shard --shards {n}` first"
                ),
                None => format!(
                    "no model versions in {dir} — run `fastpi train --model-dir {dir}` first"
                ),
            }));
        };
        let (m, n, l) = artifact.shape();
        let sh = artifact.meta.shard;
        match shard {
            Some(_) => println!(
                "serving shard {}/{} (labels {}..{} of {}) v{version} from {dir}: {m} rows folded, rank={}, {n} features",
                sh.index, sh.count, sh.label_lo, sh.label_hi, sh.label_total, artifact.rank()
            ),
            None => println!(
                "serving v{version} from {dir}: {m} rows folded, rank={}, {n} features, {l} labels",
                artifact.rank()
            ),
        }
        // named model namespaces ride along: each models/<name> child
        // store's latest version is served under `MODEL <name>`
        // (primary-only — replicas and shard slices sync one model)
        if shard.is_none() {
            for name in store.model_names()? {
                let Some((mv, art)) = store.model_ns(&name)?.load_latest()? else {
                    continue;
                };
                let (_, nf, nl) = art.shape();
                println!("  named model `{name}` v{mv}: {nf} features, {nl} labels");
                server_cfg.models.push((name, MultiLabelModel { z: art.z }));
            }
        }
        let updater = OnlineUpdater::new(artifact, updater_cfg_arg(args));
        ScoreServer::start_lifecycle(updater, Some(store), version, server_cfg)
            .map_err(crate::error::Error::Io)?
    } else {
        // no store: train in-process and serve with an in-memory lifecycle
        if shard.is_some() {
            return Err(crate::error::Error::Invalid(
                "--shard needs --model-dir (a store holding the shard set)".into(),
            ));
        }
        let name = args.str_or("dataset", "bibtex");
        let scale = args.parse_or("scale", harness::DEFAULT_SCALE);
        let seed = args.parse_or("seed", 42);
        let ds = load_dataset(&name, scale, seed, None)?;
        let job =
            PinvJob { method: Method::FastPi, alpha: args.parse_or("alpha", 0.5), k: ds.k, seed };
        println!("computing pseudoinverse for {name} (scale {scale})...");
        let rows = ds.a.rows();
        let (artifact, _) = PipelineCoordinator::new().train_model(&ds, &job, rows)?;
        let updater = OnlineUpdater::new(artifact, updater_cfg_arg(args));
        ScoreServer::start_lifecycle(updater, None, 0, server_cfg)
            .map_err(crate::error::Error::Io)?
    };
    println!(
        "scoring server on {} — verbs: SCORE <topk> j:v,... | MODEL <name> SCORE ... | LEARN <labels|-> j:v,... | VERSION | RELOAD | SHIP <have> | STATS  (Ctrl-C to stop)",
        server.addr
    );
    // machine-readable marker (line-buffered, so it flushes even when
    // piped): cluster-check and deploy scripts parse this to learn the
    // ephemeral port
    println!("FASTPI_SERVE_ADDR={}", server.addr);
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_ship(args: &Args) -> crate::error::Result<()> {
    use crate::model::{ship, ModelStore};
    let from = args.get("from").ok_or_else(|| {
        crate::error::Error::Invalid("--from HOST:PORT required (a serving primary)".into())
    })?;
    let primary = resolve_addr(from)?;
    let dir = args.get("model-dir").ok_or_else(|| {
        crate::error::Error::Invalid("--model-dir required (the local store to ship into)".into())
    })?;
    let store = ModelStore::open(std::path::Path::new(dir))?;
    let watch = args.flag("watch");
    let poll = std::time::Duration::from_millis(args.parse_or("poll-ms", 1000u64));
    loop {
        match ship::sync_once(&store, primary, ship::SHIP_TIMEOUT) {
            Ok(Some((id, art))) => {
                let (m, n, l) = art.shape();
                println!(
                    "shipped v{id} into {dir} ({m} rows folded, {n} features, {l} labels, rank {})",
                    art.rank()
                );
            }
            Ok(None) => {
                if !watch {
                    println!("up to date at v{}", store.latest_version()?.unwrap_or(0));
                }
            }
            Err(e) if watch => eprintln!("ship: {e} (retrying in {}ms)", poll.as_millis()),
            Err(e) => return Err(e),
        }
        if !watch {
            return Ok(());
        }
        std::thread::sleep(poll);
    }
}

/// Promote a follower replica to primary over the wire: one `PROMOTE`
/// round trip. The heavy lifting (sync-loop stop, completeness check,
/// epoch bump, lifecycle install) happens server-side — see
/// `coordinator/serve.rs`.
fn cmd_promote(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::text_request;
    use crate::error::Error;
    let spec = args
        .positional()
        .get(1)
        .map(String::as_str)
        .or_else(|| args.get("addr"))
        .ok_or_else(|| {
            Error::Invalid("usage: fastpi promote HOST:PORT (a running follower replica)".into())
        })?;
    let addr = resolve_addr(spec)?;
    let reply = text_request(addr, "PROMOTE").map_err(Error::Io)?;
    if reply.starts_with("OK ") {
        println!("promoted {addr}: {reply}");
        Ok(())
    } else {
        Err(Error::Invalid(format!("promote {addr} failed: {reply}")))
    }
}

/// Split the store's latest full model into a label-space shard set and
/// publish it — one atomic shard-set version — into `--out`.
fn cmd_shard(args: &Args) -> crate::error::Result<()> {
    use crate::model::{split_artifact, ModelStore};
    let dir = model_dir_arg(args, &args.str_or("dataset", "bibtex"));
    let shards: usize = args.parse_or("shards", 3usize);
    if shards < 2 {
        return Err(crate::error::Error::Invalid(
            "--shards must be ≥ 2 (1 shard is the full model — serve it without --shard)".into(),
        ));
    }
    let out = match args.get("out") {
        Some(o) => std::path::PathBuf::from(o),
        None => std::path::PathBuf::from(format!("{}-shards", dir.display())),
    };
    let store = ModelStore::open(&dir)?;
    let Some((version, artifact)) = store.load_latest()? else {
        return Err(crate::error::Error::Invalid(format!(
            "no model versions in {} — run `fastpi train` first",
            dir.display()
        )));
    };
    let (m, n, l) = artifact.shape();
    let set = split_artifact(&artifact, shards)?;
    let out_store = ModelStore::open(&out)?;
    let id = out_store.publish_shard_set(&set)?;
    println!(
        "split v{version} ({m} rows, {n} features, {l} labels, rank {}) into {shards} shards -> {} v{id}",
        artifact.rank(),
        out.display()
    );
    for s in &set {
        let sh = s.meta.shard;
        println!(
            "  shard {}/{}: labels {}..{} ({} columns of C/Z, factors shared verbatim)",
            sh.index,
            sh.count,
            sh.label_lo,
            sh.label_hi,
            sh.width()
        );
    }
    println!(
        "serve each slice with `fastpi serve --model-dir {} --shard K/{shards}`",
        out.display()
    );
    Ok(())
}

/// Live-reshard a fleet over the wire: one `RESHARD` round trip against
/// either surface of the N→M dance.
///
/// * `fastpi reshard HOST:PORT --shards M` — a serving node with a
///   store: reassemble the latest version bitwise and publish one atomic
///   M-way shard-set version (the node's own serving slot is untouched;
///   new servers pick the slices up with `--shard K/M` or `RELOAD K/M`).
/// * `fastpi reshard HOST:PORT --groups a+b,c,d` — a scatter-gather
///   router: probe the new fleet (every member live, serving the right
///   slice, in version lockstep) and flip the fan-out map epoch-style;
///   a refused flip leaves the old map serving untouched.
fn cmd_reshard(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::text_request;
    use crate::error::Error;
    let spec = args
        .positional()
        .get(1)
        .map(String::as_str)
        .or_else(|| args.get("addr"))
        .ok_or_else(|| {
            Error::Invalid(
                "usage: fastpi reshard HOST:PORT --shards M (serving node) \
                 | --groups a+b,c,d (router)"
                    .into(),
            )
        })?;
    let addr = resolve_addr(spec)?;
    let line = match (args.get("groups"), args.get("shards")) {
        (Some(groups), None) => format!("RESHARD {groups}"),
        (None, Some(m)) => {
            let m: usize = m
                .parse()
                .map_err(|_| Error::Invalid(format!("--shards must be a number, got `{m}`")))?;
            format!("RESHARD {m}")
        }
        _ => {
            return Err(Error::Invalid(
                "exactly one of --shards M (serving node) or --groups a+b,c,d (router) required"
                    .into(),
            ))
        }
    };
    let reply = text_request(addr, &line).map_err(Error::Io)?;
    if reply.starts_with("OK ") {
        println!("resharded {addr}: {reply}");
        Ok(())
    } else {
        Err(Error::Invalid(format!("reshard {addr} failed: {reply}")))
    }
}

fn cmd_route(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{Router, RouterConfig};
    let spec = args.get("replicas").ok_or_else(|| {
        crate::error::Error::Invalid("--replicas HOST:PORT,HOST:PORT,... required".into())
    })?;
    let cfg = RouterConfig { bind: args.str_or("bind", "127.0.0.1:0"), ..Default::default() };
    let router = if args.flag("sharded") {
        // scatter-gather: one ','-separated group per shard in shard
        // order, '+' joining a group's interchangeable members
        let mut groups = Vec::new();
        for g in spec.split(',').filter(|s| !s.is_empty()) {
            let mut members = Vec::new();
            for s in g.split('+').filter(|s| !s.is_empty()) {
                members.push(resolve_addr(s)?);
            }
            groups.push(members);
        }
        let n = groups.len();
        let router = Router::start_sharded(groups, cfg).map_err(crate::error::Error::Io)?;
        println!(
            "scatter-gather router on {} over {n} shard groups — verbs: SCORE (merged bitwise) | LEARN (broadcast, unanimous) | PING | STATS (per-shard versions + skew) | QUIT",
            router.addr
        );
        router
    } else {
        let mut addrs = Vec::new();
        for s in spec.split(',').filter(|s| !s.is_empty()) {
            addrs.push(resolve_addr(s)?);
        }
        let n_replicas = addrs.len();
        let router = Router::start(addrs, cfg).map_err(crate::error::Error::Io)?;
        println!(
            "router on {} fanning SCORE across {n_replicas} replicas — verbs: SCORE | PING | STATS (versions + skew) | QUIT",
            router.addr
        );
        router
    };
    println!("FASTPI_ROUTE_ADDR={}", router.addr);
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_metrics(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::multiline_request;
    use crate::error::Error;
    let Some(target) = args.positional().get(1) else {
        return Err(Error::Invalid("usage: fastpi metrics HOST:PORT".into()));
    };
    let addr = resolve_addr(target)?;
    let body = multiline_request(addr, "METRICS").map_err(Error::Io)?;
    print!("{body}");
    Ok(())
}

fn cmd_events(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::multiline_request;
    use crate::error::Error;
    let Some(target) = args.positional().get(1) else {
        return Err(Error::Invalid("usage: fastpi events HOST:PORT [N]".into()));
    };
    let addr = resolve_addr(target)?;
    let line = match args.positional().get(2) {
        Some(n) => {
            let n: usize = n.parse().map_err(|_| {
                Error::Invalid(format!("event count must be a number, got '{n}'"))
            })?;
            format!("EVENTS {n}")
        }
        None => "EVENTS".to_string(),
    };
    let body = multiline_request(addr, &line).map_err(Error::Io)?;
    print!("{body}");
    Ok(())
}

fn cmd_bench_diff(args: &Args) -> crate::error::Result<()> {
    use crate::util::bench;
    let baseline = args.str_or("baseline", "bench_baselines");
    let current = args.str_or("current", "target/bench_results");
    let max_regress: f64 = args.parse_or("max-regress", 0.20);
    let default_keys: Vec<String> = [
        "throughput_rps",
        "p50_ms",
        "p95_ms",
        "p99_ms",
        "p99_storm_ms",
        "propagation_p95_ms",
        "delta_ratio",
        "speedup_x",
        "gflops_1t",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let keys = args.parse_list("keys", &default_keys);
    let failures = bench::diff_dirs(
        std::path::Path::new(&baseline),
        std::path::Path::new(&current),
        &keys,
        max_regress,
    )?;
    if failures.is_empty() {
        println!(
            "bench-diff OK: {current} within {:.0}% of {baseline} on [{}]",
            max_regress * 100.0,
            keys.join(",")
        );
        Ok(())
    } else {
        for f in &failures {
            eprintln!("REGRESSION: {f}");
        }
        Err(crate::error::Error::Invalid(format!(
            "{} bench regression(s) vs {baseline} (refresh baselines deliberately by copying \
             target/bench_results/BENCH_*.json over bench_baselines/ in a reviewed commit)",
            failures.len()
        )))
    }
}

fn cmd_update(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{PinvJob, PipelineCoordinator};
    use crate::data::load_dataset;
    use crate::model::{ModelStore, OnlineUpdater};
    use crate::sparse::{io as sio, Csr};
    let dir = model_dir_arg(args, &args.str_or("dataset", "bibtex"));
    let store = ModelStore::open(&dir)?;
    let Some((version, artifact)) = store.load_latest()? else {
        return Err(crate::error::Error::Invalid(format!(
            "no model versions in {} — run `fastpi train` first",
            dir.display()
        )));
    };
    let meta = artifact.meta.clone();
    let (_, _, l) = artifact.shape();
    let mut updater = OnlineUpdater::new(artifact, updater_cfg_arg(args));

    // new rows: an explicit MatrixMarket file (folds without moving the
    // dataset cursor), or the dataset's held-out stream starting at the
    // stored cursor (dataset is loaded once and reused for the recompute
    // comparison below)
    let mut loaded_ds = None;
    let rep = if let Some(rows_path) = args.get("rows") {
        let a = sio::read_matrix_market(std::path::Path::new(rows_path))?;
        let y = match args.get("labels") {
            Some(p) => sio::read_matrix_market(std::path::Path::new(p))?,
            None => Csr::zeros(a.rows(), l),
        };
        updater.apply_block(&a, &y)?
    } else {
        if meta.dataset.is_empty() {
            return Err(crate::error::Error::Invalid(
                "model has no dataset identity — pass --rows/--labels files".into(),
            ));
        }
        let ds = loaded_ds.insert(load_dataset(&meta.dataset, meta.scale, meta.seed, None)?);
        let start = meta.dataset_rows as usize;
        if start >= ds.a.rows() {
            println!(
                "v{version}: all {} rows of {} already folded — nothing to update",
                ds.a.rows(),
                meta.dataset
            );
            return Ok(());
        }
        let take = args.parse_or("batch", 64usize).min(ds.a.rows() - start);
        let a_new = ds.a.block(start, 0, take, ds.a.cols());
        let y_new = ds.y.block(start, 0, take, ds.y.cols());
        updater.apply_dataset_block(&a_new, &y_new)?
    };
    let new_version = store.publish(updater.artifact())?;
    println!(
        "v{version} -> v{new_version}: folded {} rows in {:.3}s (rank={} drift={:.3e} total_drift={:.3e})",
        rep.rows, rep.secs, rep.rank, rep.drift_inc, rep.drift_total
    );

    // the paper's speed claim as a serving-lifecycle metric: the same rows
    // via a full FastPI recompute on the accumulated dataset prefix
    if let (Some(ds), false) = (&loaded_ds, args.flag("no-compare")) {
        let new_meta = &updater.artifact().meta;
        let upto = (new_meta.dataset_rows as usize).min(ds.a.rows());
        let job = PinvJob { method: Method::FastPi, alpha: meta.alpha, k: meta.k, seed: meta.seed };
        let t = std::time::Instant::now();
        let (resolved, _) = PipelineCoordinator::new().train_model(ds, &job, upto)?;
        let recompute_secs = t.elapsed().as_secs_f64();
        println!(
            "incremental={:.3}s full-recompute={:.3}s speedup={:.1}x",
            rep.secs,
            recompute_secs,
            recompute_secs / rep.secs.max(1e-9)
        );
        if rep.needs_resolve || args.flag("resolve") {
            if new_meta.rows_trained > new_meta.dataset_rows {
                println!(
                    "note: re-solve covers the {upto}-row dataset prefix; {} ad-hoc learned rows are not in it",
                    new_meta.rows_trained - new_meta.dataset_rows
                );
            }
            let rv = store.publish(&resolved)?;
            println!(
                "re-solve threshold crossed — published full re-solve as v{rv} (drift reset)"
            );
        }
    } else if rep.needs_resolve {
        println!(
            "re-solve threshold crossed (drift={:.3e}, rows_since_solve={}) — retrain with `fastpi train`",
            rep.drift_total,
            updater.artifact().meta.rows_since_solve
        );
    }
    if let Some(keep) = args.get("gc") {
        // deleting versions on a malformed argument would be destructive
        let keep: usize = keep.parse().map_err(|_| {
            crate::error::Error::Invalid(format!("bad --gc value `{keep}` (want a count)"))
        })?;
        let removed = store.gc(keep)?;
        println!("gc: removed {removed} old versions (kept newest {keep})");
    }
    Ok(())
}

/// Headless end-to-end smoke of the model lifecycle: serve the store's
/// latest version and drive SCORE/LEARN/RELOAD/VERSION/STATS over TCP,
/// asserting the save→load→update→swap loop behaves. Exits non-zero on any
/// mismatch, so CI can gate on it after a separate `train` process — the
/// restart between the two is the point.
fn cmd_lifecycle_check(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{multiline_request, text_request, ScoreServer, ServerConfig};
    use crate::error::Error;
    use crate::model::{ModelStore, OnlineUpdater};
    use crate::obs::registry::parse_scalars;
    let dir = model_dir_arg(args, &args.str_or("dataset", "bibtex"));
    let store = ModelStore::open(&dir)?;
    let Some((version, artifact)) = store.load_latest()? else {
        return Err(Error::Invalid(format!(
            "no model versions in {} — run `fastpi train` first",
            dir.display()
        )));
    };
    let (_, n, _) = artifact.shape();
    // the overload step below serves this same model under a tiny queue
    let flood_z = artifact.z.clone();
    let updater = OnlineUpdater::new(artifact, updater_cfg_arg(args));
    let server = ScoreServer::start_lifecycle(updater, Some(store), version, ServerConfig::default())
        .map_err(Error::Io)?;
    let addr = server.addr;

    let check = |what: &str, got: &str, want_prefix: &str| -> crate::error::Result<()> {
        if got.starts_with(want_prefix) {
            println!("  {what}: {got}");
            Ok(())
        } else {
            Err(Error::Invalid(format!("{what}: expected `{want_prefix}...`, got `{got}`")))
        }
    };
    let req = |line: &str| text_request(addr, line).map_err(Error::Io);

    check("PING", &req("PING")?, "PONG")?;
    check("VERSION", &req("VERSION")?, &format!("VERSION id={version} "))?;
    let feats = format!("0:1.0,{}:0.5", n.saturating_sub(1));
    let score1 = req(&format!("SCORE 3 {feats}"))?;
    check("SCORE", &score1, "OK ")?;
    check("RELOAD", &req("RELOAD")?, &format!("OK version={version}"))?;
    let score2 = req(&format!("SCORE 3 {feats}"))?;
    if score1 != score2 {
        return Err(Error::Invalid(format!(
            "SCORE changed across RELOAD of the same version: `{score1}` vs `{score2}`"
        )));
    }
    println!("  SCORE after RELOAD: identical reply");
    check("LEARN", &req(&format!("LEARN 0 {feats}"))?, "OK version=")?;
    // learn_batch defaults to 1, so the fold + hot swap already happened
    check("VERSION after LEARN", &req("VERSION")?, &format!("VERSION id={} ", version + 1))?;
    let score3 = req(&format!("SCORE 3 {feats}"))?;
    check("SCORE after swap", &score3, "OK ")?;
    let stats = req("STATS")?;
    check("STATS", &stats, "STATS served=")?;
    for field in ["rejected=", "queue_depth=", "swaps=", "learned="] {
        if !stats.contains(field) {
            return Err(Error::Invalid(format!("STATS missing `{field}`: {stats}")));
        }
    }

    // METRICS must parse, count the gemm work actually done, and stay
    // monotone on every cumulative family between snapshots.
    let gemm_key = "fastpi_stage_ns_count{stage=\"gemm\"}";
    let find = |scalars: &[(String, f64)], key: &str| -> crate::error::Result<f64> {
        scalars
            .iter()
            .find(|(k, _)| k == key)
            .map(|&(_, v)| v)
            .ok_or_else(|| Error::Invalid(format!("METRICS missing `{key}`")))
    };
    let metrics1 = multiline_request(addr, "METRICS").map_err(Error::Io)?;
    let scalars1 = parse_scalars(&metrics1).map_err(Error::Invalid)?;
    let gemm1 = find(&scalars1, gemm_key)?;
    for _ in 0..8 {
        let r = req(&format!("SCORE 3 {feats}"))?;
        if !r.starts_with("OK ") {
            return Err(Error::Invalid(format!("SCORE during METRICS check failed: {r}")));
        }
    }
    let metrics2 = multiline_request(addr, "METRICS").map_err(Error::Io)?;
    let scalars2 = parse_scalars(&metrics2).map_err(Error::Invalid)?;
    let gemm2 = find(&scalars2, gemm_key)?;
    if gemm2 < gemm1 + 8.0 {
        return Err(Error::Invalid(format!(
            "gemm span count did not advance with traffic: {gemm1} -> {gemm2} after 8 SCOREs"
        )));
    }
    for (k, v1) in &scalars1 {
        let base = k.split('{').next().unwrap_or(k);
        let cumulative = k.contains("_bucket{")
            || base.ends_with("_total")
            || base.ends_with("_count")
            || base.ends_with("_sum")
            || base.ends_with("_total_ns");
        if !cumulative {
            continue;
        }
        let v2 = find(&scalars2, k)?;
        if v2 < *v1 {
            return Err(Error::Invalid(format!(
                "cumulative series `{k}` went backwards between METRICS snapshots: {v1} -> {v2}"
            )));
        }
    }
    println!("  METRICS: {} series, gemm count {gemm1} -> {gemm2}, all monotone", scalars2.len());

    // EVENTS must carry the lifecycle we just drove, then drain.
    let events = multiline_request(addr, "EVENTS").map_err(Error::Io)?;
    for kind in ["kind=learn", "kind=swap"] {
        if !events.contains(kind) {
            return Err(Error::Invalid(format!("EVENTS missing `{kind}`:\n{events}")));
        }
    }
    let drained = multiline_request(addr, "EVENTS").map_err(Error::Io)?;
    if !drained.is_empty() {
        return Err(Error::Invalid(format!("EVENTS did not drain: second read got\n{drained}")));
    }
    println!("  EVENTS: learn + swap recorded, journal drained");
    server.shutdown();

    // Overload discipline: flood a deliberately tiny-throughput server
    // past its shed threshold — every reply must be OK or a fast
    // `ERR busy` (never a queue timeout), STATS must reconcile exactly
    // with the client-observed counts, and once the flood drains,
    // steady-state traffic sees zero errors.
    let flood_cfg = ServerConfig {
        max_batch: 1, // one row per drain keeps a backlog alive under the flood
        max_wait: std::time::Duration::ZERO,
        queue_capacity: 64,
        shed_depth: 2,
        slo: Some(std::time::Duration::from_millis(50)),
        ..Default::default()
    };
    let flood = ScoreServer::start(crate::regress::MultiLabelModel { z: flood_z }, flood_cfg)
        .map_err(Error::Io)?;
    let flood_addr = flood.addr;
    let (threads, per) = (8usize, 25usize);
    let (ok, busy) = std::thread::scope(|s| -> crate::error::Result<(usize, usize)> {
        let mut handles = Vec::new();
        for _ in 0..threads {
            handles.push(s.spawn(move || -> Result<(usize, usize), String> {
                let (mut ok, mut busy) = (0usize, 0usize);
                for _ in 0..per {
                    let r = text_request(flood_addr, "SCORE 1 0:1.0")
                        .map_err(|e| format!("flood request io: {e}"))?;
                    if r.starts_with("OK ") {
                        ok += 1;
                    } else if r == "ERR busy" {
                        busy += 1;
                    } else {
                        return Err(format!("flood got `{r}` — only OK/ERR busy are allowed"));
                    }
                }
                Ok((ok, busy))
            }));
        }
        let (mut ok, mut busy) = (0usize, 0usize);
        for h in handles {
            let (o, b) = h.join().expect("flood thread panicked").map_err(Error::Invalid)?;
            ok += o;
            busy += b;
        }
        Ok((ok, busy))
    })?;
    if ok + busy != threads * per {
        return Err(Error::Invalid(format!(
            "flood accounting broken: {ok} OK + {busy} busy != {}",
            threads * per
        )));
    }
    let stats = text_request(flood_addr, "STATS").map_err(Error::Io)?;
    let stat_field = |key: &str| -> crate::error::Result<usize> {
        stats
            .split_whitespace()
            .find_map(|tok| tok.strip_prefix(key)?.parse().ok())
            .ok_or_else(|| Error::Invalid(format!("STATS missing `{key}`: {stats}")))
    };
    let (served, shed) = (stat_field("served=")?, stat_field("shed=")?);
    let (rejected, deadlines) = (stat_field("rejected=")?, stat_field("deadlines=")?);
    if served != ok || shed != busy || rejected != 0 || deadlines != 0 {
        return Err(Error::Invalid(format!(
            "STATS does not reconcile with the flood: clients saw {ok} OK / {busy} busy, {stats}"
        )));
    }
    // recovery: the drained server serves steady traffic error-free
    for i in 0..10 {
        let r = text_request(flood_addr, "SCORE 1 0:1.0").map_err(Error::Io)?;
        if !r.starts_with("OK ") {
            return Err(Error::Invalid(format!("post-flood request {i} got `{r}`")));
        }
    }
    flood.shutdown();
    println!(
        "  overload: {ok} served + {busy} shed of {} (STATS reconciled), steady traffic clean",
        threads * per
    );

    println!("lifecycle-check OK: v{version} served, reloaded, learned into v{}", version + 1);
    Ok(())
}

/// Child server processes plus scratch stores for the headless cluster
/// checks; everything dies with the check, pass or fail.
struct Fleet {
    exe: std::path::PathBuf,
    children: Vec<std::process::Child>,
    scratch: Vec<std::path::PathBuf>,
}

impl Fleet {
    fn new() -> crate::error::Result<Fleet> {
        Ok(Fleet {
            exe: std::env::current_exe().map_err(crate::error::Error::Io)?,
            children: Vec::new(),
            scratch: Vec::new(),
        })
    }

    /// Spawn `fastpi <argv>` as a child process and wait for its
    /// `FASTPI_SERVE_ADDR=` marker.
    fn spawn_server(&mut self, argv: &[String]) -> crate::error::Result<std::net::SocketAddr> {
        use crate::error::Error;
        use std::io::BufRead;
        use std::process::{Command, Stdio};
        let mut child = Command::new(&self.exe)
            .args(argv)
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .map_err(Error::Io)?;
        let stdout = child.stdout.take().expect("piped stdout");
        let (tx, rx) = std::sync::mpsc::channel();
        // reader thread: forward the addr marker, then keep draining so
        // the child can never block on a full stdout pipe
        std::thread::spawn(move || {
            for line in std::io::BufReader::new(stdout).lines() {
                let Ok(line) = line else { break };
                if let Some(addr) = line.strip_prefix("FASTPI_SERVE_ADDR=") {
                    let _ = tx.send(addr.to_string());
                }
            }
        });
        self.children.push(child);
        let addr = rx.recv_timeout(std::time::Duration::from_secs(120)).map_err(|_| {
            Error::Invalid("spawned server never reported FASTPI_SERVE_ADDR".into())
        })?;
        addr.parse().map_err(|_| Error::Invalid(format!("bad server address `{addr}`")))
    }

    /// Kill one child (by spawn order) mid-check — the failure-injection
    /// half of `failover-check`. SIGKILL + reap, so its ports refuse
    /// connections immediately.
    fn kill(&mut self, index: usize) {
        if let Some(c) = self.children.get_mut(index) {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
            let _ = c.wait();
        }
        for d in &self.scratch {
            let _ = std::fs::remove_dir_all(d);
        }
    }
}

/// Headless replica fan-out check: spawn a primary and N follower
/// *processes* off one trained store, put the in-process router in front
/// of the followers, and assert the replication acceptance properties —
/// every replica converges on the primary's version and serves
/// byte-identical SCORE replies, publishes on the primary propagate until
/// the router observes skew 0, and not one request is dropped or errored
/// along the way. The ≥3-OS-process topology is the point: this is the
/// multi-host story exercised on one machine.
fn cmd_cluster_check(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{multiline_request, text_request, Router, RouterConfig};
    use crate::error::Error;
    use crate::model::ModelStore;
    use crate::obs::registry::parse_scalars;
    use std::time::{Duration, Instant};

    let dir = model_dir_arg(args, &args.str_or("dataset", "bibtex"));
    let store = ModelStore::open(&dir)?;
    let Some((v1, artifact)) = store.load_latest()? else {
        return Err(Error::Invalid(format!(
            "no model versions in {} — run `fastpi train` first",
            dir.display()
        )));
    };
    drop(store);
    let (_, n, l) = artifact.shape();
    let n_replicas: usize = args.parse_or("replicas", 3usize);
    let learns: u64 = args.parse_or("learns", 3u64);
    let routed_requests: usize = args.parse_or("requests", 24usize);
    let mut fleet = Fleet::new()?;

    // one primary process serving the trained store
    let primary = fleet.spawn_server(&[
        "serve".into(),
        "--model-dir".into(),
        dir.display().to_string(),
        "--learn-batch".into(),
        "1".into(),
    ])?;
    println!("primary on {primary} serving v{v1} from {}", dir.display());

    // N follower processes, each with its own empty local store
    let mut replica_addrs = Vec::new();
    for i in 0..n_replicas {
        let rdir =
            std::env::temp_dir().join(format!("fastpi_cluster_{}_{i}", std::process::id()));
        let _ = std::fs::remove_dir_all(&rdir);
        fleet.scratch.push(rdir.clone());
        let addr = fleet.spawn_server(&[
            "serve".into(),
            "--replica-of".into(),
            primary.to_string(),
            "--model-dir".into(),
            rdir.display().to_string(),
            "--poll-ms".into(),
            "25".into(),
        ])?;
        println!("replica {i} on {addr} (store {})", rdir.display());
        replica_addrs.push(addr);
    }

    // in-process front-end router over the followers
    let router =
        Router::start(replica_addrs.clone(), RouterConfig::default()).map_err(Error::Io)?;

    let req = |addr, line: &str| text_request(addr, line).map_err(Error::Io);
    let wait_all_at = |want: u64, what: &str| -> crate::error::Result<()> {
        let deadline = Instant::now() + Duration::from_secs(60);
        'outer: loop {
            for &addr in &replica_addrs {
                let v = req(addr, "VERSION")?;
                if !v.starts_with(&format!("VERSION id={want} ")) {
                    if Instant::now() > deadline {
                        return Err(Error::Invalid(format!(
                            "{what}: {addr} stuck at `{v}`, want id={want}"
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(20));
                    continue 'outer;
                }
            }
            return Ok(());
        }
    };

    // (a) every replica converges on the primary's version
    wait_all_at(v1, "initial sync")?;
    println!("  all {n_replicas} replicas at v{v1}");

    // (b) byte-identical scores at the same version
    let probe = format!("SCORE 3 0:1.0,{}:0.5", n.saturating_sub(1));
    let want = req(primary, &probe)?;
    if !want.starts_with("OK ") {
        return Err(Error::Invalid(format!("primary SCORE failed: {want}")));
    }
    for &addr in &replica_addrs {
        let got = req(addr, &probe)?;
        if got != want {
            return Err(Error::Invalid(format!(
                "replica {addr} diverged at v{v1}: `{got}` vs `{want}`"
            )));
        }
    }
    println!("  SCORE byte-identical across primary + {n_replicas} replicas");

    // (c) fan-out through the router: every routed request answers OK
    for i in 0..routed_requests {
        let got = req(router.addr, &probe)?;
        if got != want {
            return Err(Error::Invalid(format!("routed request {i} got `{got}`")));
        }
    }

    // (d) publishes on the primary propagate to the whole fleet
    for k in 0..learns {
        let line = format!("LEARN {} {}:1.0", k as usize % l, k as usize % n);
        let reply = req(primary, &line)?;
        if !reply.starts_with(&format!("OK version={} ", v1 + k + 1)) {
            return Err(Error::Invalid(format!("LEARN {k}: {reply}")));
        }
    }
    wait_all_at(v1 + learns, "post-LEARN convergence")?;
    let stats = req(router.addr, "STATS")?;
    if !stats.contains(" skew=0") {
        return Err(Error::Invalid(format!("fleet should be converged: {stats}")));
    }
    println!("  {learns} publishes propagated to every replica ({stats})");

    // (e) still byte-identical at the new version, and zero routed errors
    let want = req(primary, &probe)?;
    for &addr in &replica_addrs {
        let got = req(addr, &probe)?;
        if got != want {
            return Err(Error::Invalid(format!(
                "replica {addr} diverged after propagation: `{got}` vs `{want}`"
            )));
        }
    }
    let errors = router.stats.errors.load(std::sync::atomic::Ordering::Relaxed);
    let routed = router.stats.routed.load(std::sync::atomic::Ordering::Relaxed);
    if errors != 0 || routed < routed_requests {
        return Err(Error::Invalid(format!(
            "router dropped requests: routed={routed} errors={errors}"
        )));
    }

    // (f) the router's merged METRICS equals the sum of the members'
    // — the fleet view is an exact merge, not a sample. The router's
    // view is fetched FIRST so member-local traffic between the two
    // reads can only push member counts above the merged snapshot,
    // never below.
    let merged = multiline_request(router.addr, "METRICS").map_err(Error::Io)?;
    let merged_scalars = parse_scalars(&merged).map_err(Error::Invalid)?;
    let gemm_key = "fastpi_stage_ns_count{stage=\"gemm\"}";
    let merged_gemm = merged_scalars
        .iter()
        .find(|(k, _)| k == gemm_key)
        .map(|&(_, v)| v)
        .ok_or_else(|| Error::Invalid(format!("router METRICS missing `{gemm_key}`")))?;
    let mut member_gemm = 0.0;
    for &addr in &replica_addrs {
        let body = multiline_request(addr, "METRICS").map_err(Error::Io)?;
        let scalars = parse_scalars(&body).map_err(Error::Invalid)?;
        member_gemm += scalars
            .iter()
            .find(|(k, _)| k == gemm_key)
            .map(|&(_, v)| v)
            .ok_or_else(|| {
                Error::Invalid(format!("replica {addr} METRICS missing `{gemm_key}`"))
            })?;
    }
    if merged_gemm > member_gemm || merged_gemm < routed_requests as f64 {
        return Err(Error::Invalid(format!(
            "merged METRICS inconsistent: router sees gemm count {merged_gemm}, \
             members sum to {member_gemm}, routed {routed_requests}"
        )));
    }
    println!(
        "  METRICS merge consistent: router gemm count {merged_gemm} <= member sum {member_gemm}"
    );

    router.shutdown();
    println!(
        "cluster-check OK: {n_replicas}-replica fleet converged v{v1} -> v{} with zero dropped requests",
        v1 + learns
    );
    Ok(())
}

/// Headless label-space sharding check — the sharded-equals-unsharded
/// acceptance property, across real OS processes: split the trained model
/// into N shards, serve every shard as its own process off one shard
/// store, serve the unsharded model as a reference process, scatter-gather
/// route over the shard fleet, and assert (a) every routed SCORE reply is
/// byte-identical to the reference server's, (b) broadcast LEARNs advance
/// every shard unanimously with replies byte-identical to the reference
/// server's, and (c) the reassembled shard set is bitwise the reference
/// store's model — factors and Z.
fn cmd_shard_check(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{text_request, Router, RouterConfig};
    use crate::error::Error;
    use crate::model::{reassemble, split_artifact, ModelStore};

    let dir = model_dir_arg(args, &args.str_or("dataset", "bibtex"));
    let shards: usize = args.parse_or("shards", 3usize);
    let learns: u64 = args.parse_or("learns", 3u64);
    let source = ModelStore::open(&dir)?;
    let Some((src_version, artifact)) = source.load_latest()? else {
        return Err(Error::Invalid(format!(
            "no model versions in {} — run `fastpi train` first",
            dir.display()
        )));
    };
    drop(source);
    let (_, n, l) = artifact.shape();

    // scratch stores: an unsharded reference copy and the shard set, both
    // at v1 so version advance stays comparable across the two fleets
    let base = std::env::temp_dir().join(format!("fastpi_shardcheck_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let ref_dir = base.join("ref");
    let shard_dir = base.join("shards");
    let mut fleet = Fleet::new()?;
    fleet.scratch.push(base.clone());
    let ref_store = ModelStore::open(&ref_dir)?;
    assert_eq!(ref_store.publish(&artifact)?, 1, "fresh reference store starts at v1");
    let set = split_artifact(&artifact, shards)?;
    let shard_store = ModelStore::open(&shard_dir)?;
    assert_eq!(shard_store.publish_shard_set(&set)?, 1, "fresh shard store starts at v1");
    println!(
        "split v{src_version} ({l} labels, rank {}) into {shards} shards under {}",
        artifact.rank(),
        base.display()
    );

    // the unsharded reference process + one process per shard
    let reference = fleet.spawn_server(&[
        "serve".into(),
        "--model-dir".into(),
        ref_dir.display().to_string(),
        "--learn-batch".into(),
        "1".into(),
    ])?;
    println!("reference (unsharded) on {reference}");
    let mut shard_addrs = Vec::new();
    for k in 0..shards {
        let addr = fleet.spawn_server(&[
            "serve".into(),
            "--model-dir".into(),
            shard_dir.display().to_string(),
            "--shard".into(),
            format!("{k}/{shards}"),
            "--learn-batch".into(),
            "1".into(),
        ])?;
        println!("shard {k}/{shards} on {addr}");
        shard_addrs.push(addr);
    }
    let router = Router::start_sharded(
        shard_addrs.iter().map(|&a| vec![a]).collect(),
        RouterConfig::default(),
    )
    .map_err(Error::Io)?;

    let req = |addr, line: &str| text_request(addr, line).map_err(Error::Io);
    let probes = [
        format!("SCORE 5 0:1.0,{}:0.5", n.saturating_sub(1)),
        "SCORE 1 0:1.0".to_string(),
        format!("SCORE {l} 1:0.25,2:-1.0"), // topk = the whole label space
        "SCORE 3 ".to_string(),             // empty feature list
    ];

    // (a) scatter-gather SCORE ≡ unsharded SCORE, byte for byte
    let mut compared = 0usize;
    for probe in &probes {
        let want = req(reference, probe)?;
        if !want.starts_with("OK ") {
            return Err(Error::Invalid(format!("reference SCORE failed: {want}")));
        }
        let got = req(router.addr, probe)?;
        if got != want {
            return Err(Error::Invalid(format!(
                "sharded reply diverged on `{probe}`:\n  sharded:   {got}\n  unsharded: {want}"
            )));
        }
        compared += 1;
    }
    println!("  {compared} scatter-gather SCORE replies byte-identical to the unsharded server");

    // (b) broadcast LEARN: unanimous advance, reply byte-identical to the
    // unsharded server folding the same example (deterministic folds)
    for step in 0..learns {
        let line = format!("LEARN {} {}:1.0", step as usize % l, step as usize % n);
        let sharded = req(router.addr, &line)?;
        let unsharded = req(reference, &line)?;
        let want_version = 2 + step;
        if sharded != unsharded {
            return Err(Error::Invalid(format!(
                "LEARN {step} diverged:\n  sharded:   {sharded}\n  unsharded: {unsharded}"
            )));
        }
        if !sharded.starts_with(&format!("OK version={want_version} ")) {
            return Err(Error::Invalid(format!("LEARN {step}: {sharded}")));
        }
    }
    let v_final = 1 + learns;
    for (k, &addr) in shard_addrs.iter().enumerate() {
        let v = req(addr, "VERSION")?;
        let want = format!("VERSION id={v_final} ");
        if !v.starts_with(&want) || !v.ends_with(&format!("shard={k}/{shards}")) {
            return Err(Error::Invalid(format!(
                "shard {k} out of step after broadcast LEARN: `{v}` (want id={v_final})"
            )));
        }
    }
    let stats = req(router.addr, "STATS")?;
    if !stats.contains(" skew=0") || !stats.contains(&format!("shards={shards}")) {
        return Err(Error::Invalid(format!("shard fleet should be converged: {stats}")));
    }
    println!("  {learns} broadcast LEARNs advanced every shard to v{v_final} unanimously ({stats})");

    // (c) post-LEARN scoring still identical, and the reassembled shard
    // set is bitwise the unsharded store's model
    for probe in &probes {
        let want = req(reference, probe)?;
        let got = req(router.addr, probe)?;
        if got != want {
            return Err(Error::Invalid(format!("post-LEARN divergence on `{probe}`")));
        }
    }
    let (ref_v, reference_model) = ModelStore::open(&ref_dir)?.load_latest()?.unwrap();
    if ref_v != v_final {
        return Err(Error::Invalid(format!(
            "reference store at v{ref_v}, expected v{v_final}"
        )));
    }
    let back = reassemble(&ModelStore::open(&shard_dir)?.load_shard_set(v_final)?)?;
    for (name, a, b) in [
        ("U", back.svd.u.data(), reference_model.svd.u.data()),
        ("Vt", back.svd.vt.data(), reference_model.svd.vt.data()),
        ("C", back.c.data(), reference_model.c.data()),
        ("Z", back.z.data(), reference_model.z.data()),
    ] {
        if a != b {
            return Err(Error::Invalid(format!(
                "reassembled {name} is not bitwise the unsharded model after sharded LEARN"
            )));
        }
    }
    if back.svd.s != reference_model.svd.s || back.s_inv != reference_model.s_inv {
        return Err(Error::Invalid(
            "reassembled Σ/Σ⁺ is not bitwise the unsharded model".into(),
        ));
    }
    let errors = router.stats.errors.load(std::sync::atomic::Ordering::Relaxed);
    if errors != 0 {
        return Err(Error::Invalid(format!("router reported {errors} errors")));
    }
    router.shutdown();
    println!(
        "shard-check OK: {shards}-shard fleet scored bitwise-identically to the unsharded model \
         and broadcast LEARN kept it in lockstep v1 -> v{v_final} (factors + Z reassemble bitwise)"
    );
    Ok(())
}

/// Headless fleet-resilience check — sharded replica chains under failure
/// injection, across real OS processes:
///
/// 1. the trained model is split into N shards; each shard group gets a
///    primary process AND a snapshot-shipped follower process, with the
///    scatter-gather router (multi-member groups) in front, plus an
///    unsharded reference process for bitwise comparison;
/// 2. **degraded serving**: under concurrent SCORE load, one member of
///    every group is killed (group 0 loses its PRIMARY, the others lose
///    their followers) — every routed reply must still arrive and be
///    byte-identical to the reference's (health circuits + sibling retry);
/// 3. **promotion**: group 0's follower is `PROMOTE`d in place — broadcast
///    LEARN service is restored (replies byte-identical to the reference,
///    unanimous version advance) and STATS skew over the reachable fleet
///    returns to 0;
/// 4. zero routed errors end to end, and STATS `unhealthy=` agrees with
///    the kill list.
fn cmd_failover_check(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{text_request, Router, RouterConfig};
    use crate::error::Error;
    use crate::model::{split_artifact, ModelStore};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::{Duration, Instant};

    let dir = model_dir_arg(args, &args.str_or("dataset", "bibtex"));
    let shards: usize = args.parse_or("shards", 2usize);
    let learns: u64 = args.parse_or("learns", 3u64);
    let load_threads: usize = args.parse_or("clients", 4usize);
    let per_thread: usize = args.parse_or("requests", 30usize);
    let source = ModelStore::open(&dir)?;
    let Some((src_version, artifact)) = source.load_latest()? else {
        return Err(Error::Invalid(format!(
            "no model versions in {} — run `fastpi train` first",
            dir.display()
        )));
    };
    drop(source);
    let (_, n, l) = artifact.shape();

    // scratch stores: unsharded reference, the shard set, and one empty
    // local store per follower — all at comparable version sequences
    let base = std::env::temp_dir().join(format!("fastpi_failover_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let ref_dir = base.join("ref");
    let shard_dir = base.join("shards");
    let mut fleet = Fleet::new()?;
    fleet.scratch.push(base.clone());
    assert_eq!(ModelStore::open(&ref_dir)?.publish(&artifact)?, 1);
    let set = split_artifact(&artifact, shards)?;
    assert_eq!(ModelStore::open(&shard_dir)?.publish_shard_set(&set)?, 1);
    println!(
        "split v{src_version} ({l} labels, rank {}) into {shards} shard groups under {}",
        artifact.rank(),
        base.display()
    );

    // spawn order (== Fleet child indices): reference, shard primaries,
    // then one follower per shard
    let reference = fleet.spawn_server(&[
        "serve".into(),
        "--model-dir".into(),
        ref_dir.display().to_string(),
        "--learn-batch".into(),
        "1".into(),
    ])?;
    println!("reference (unsharded) on {reference}");
    let mut primary_addrs = Vec::new();
    for k in 0..shards {
        let addr = fleet.spawn_server(&[
            "serve".into(),
            "--model-dir".into(),
            shard_dir.display().to_string(),
            "--shard".into(),
            format!("{k}/{shards}"),
            "--learn-batch".into(),
            "1".into(),
        ])?;
        println!("shard {k}/{shards} primary on {addr}");
        primary_addrs.push(addr);
    }
    let mut follower_addrs = Vec::new();
    for k in 0..shards {
        let fdir = base.join(format!("follower{k}"));
        let addr = fleet.spawn_server(&[
            "serve".into(),
            "--shard".into(),
            format!("{k}/{shards}"),
            "--replica-of".into(),
            primary_addrs[k].to_string(),
            "--model-dir".into(),
            fdir.display().to_string(),
            "--poll-ms".into(),
            "25".into(),
            // fleet-matching lifecycle config for the eventual PROMOTE
            "--learn-batch".into(),
            "1".into(),
        ])?;
        println!("shard {k}/{shards} follower on {addr}");
        follower_addrs.push(addr);
    }
    let primary_child = |k: usize| 1 + k;
    let follower_child = |k: usize| 1 + shards + k;

    // multi-member shard groups: [primary_k, follower_k]; the long
    // cooldown keeps killed members' circuits deterministically open for
    // the whole check
    let groups: Vec<Vec<std::net::SocketAddr>> = (0..shards)
        .map(|k| vec![primary_addrs[k], follower_addrs[k]])
        .collect();
    let cfg = RouterConfig {
        upstream_timeout: Duration::from_secs(5),
        fail_threshold: 2,
        health_cooldown: Duration::from_secs(120),
        ..Default::default()
    };
    let router = Router::start_sharded(groups, cfg).map_err(Error::Io)?;

    let req = |addr, line: &str| text_request(addr, line).map_err(Error::Io);

    // every follower serving v1 before the shooting starts
    let deadline = Instant::now() + Duration::from_secs(60);
    for &addr in &follower_addrs {
        loop {
            let v = req(addr, "VERSION")?;
            if v.starts_with("VERSION id=1 ") {
                break;
            }
            if Instant::now() > deadline {
                return Err(Error::Invalid(format!("follower {addr} never synced: {v}")));
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    // expected replies pinned off the unsharded reference
    let probes = [
        format!("SCORE 5 0:1.0,{}:0.5", n.saturating_sub(1)),
        "SCORE 1 0:1.0".to_string(),
        format!("SCORE {l} 1:0.25,2:-1.0"),
        "SCORE 3 ".to_string(),
    ];
    let mut want = Vec::new();
    for probe in &probes {
        let w = req(reference, probe)?;
        if !w.starts_with("OK ") {
            return Err(Error::Invalid(format!("reference SCORE failed: {w}")));
        }
        want.push(w);
    }

    // phase 2 — degraded serving: concurrent load through the router;
    // mid-load, kill one member per group (group 0: the PRIMARY — its
    // follower is promoted in phase 3; other groups: the follower)
    let progress = AtomicUsize::new(0);
    let router_addr = router.addr;
    let total = load_threads * per_thread;
    std::thread::scope(|s| -> crate::error::Result<()> {
        let mut handles = Vec::new();
        for t in 0..load_threads {
            let probes = &probes;
            let want = &want;
            let progress = &progress;
            handles.push(s.spawn(move || -> Result<usize, String> {
                let mut served = 0usize;
                for i in 0..per_thread {
                    let pi = (t + i) % probes.len();
                    let got = text_request(router_addr, &probes[pi])
                        .map_err(|e| format!("request io: {e}"))?;
                    if got != want[pi] {
                        return Err(format!(
                            "degraded reply diverged on `{}`:\n  got:  {got}\n  want: {}",
                            probes[pi], want[pi]
                        ));
                    }
                    served += 1;
                    progress.fetch_add(1, Ordering::Relaxed);
                }
                Ok(served)
            }));
        }
        // let the fleet serve healthy for a moment, then shoot
        let kill_after = total / 3;
        let deadline = Instant::now() + Duration::from_secs(120);
        while progress.load(Ordering::Relaxed) < kill_after {
            if Instant::now() > deadline {
                return Err(Error::Invalid("load never reached the kill point".into()));
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        fleet.kill(primary_child(0));
        for k in 1..shards {
            fleet.kill(follower_child(k));
        }
        println!(
            "  killed shard-0 primary + {} follower(s) mid-load (after {} requests)",
            shards - 1,
            progress.load(Ordering::Relaxed)
        );
        let mut served_total = 0usize;
        for h in handles {
            match h.join().expect("load thread panicked") {
                Ok(srv) => served_total += srv,
                Err(e) => return Err(Error::Invalid(e)),
            }
        }
        if served_total != total {
            return Err(Error::Invalid(format!(
                "dropped requests under failure: served {served_total} of {total}"
            )));
        }
        Ok(())
    })?;
    let retries = router.stats.retries.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "  {total} routed SCOREs all byte-identical to the reference with one member down per group ({retries} request lines retried onto siblings)"
    );

    // STATS must agree with the kill list: probe twice (probe failures
    // feed the same circuits fan-out uses), then read unhealthy=
    let _ = req(router.addr, "STATS")?;
    let stats = req(router.addr, "STATS")?;
    let unhealthy: usize = stats
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix("unhealthy=")?.parse().ok())
        .ok_or_else(|| Error::Invalid(format!("STATS missing unhealthy=: {stats}")))?;
    if unhealthy != shards {
        return Err(Error::Invalid(format!(
            "unhealthy={unhealthy}, expected {shards} (one killed member per group): {stats}"
        )));
    }

    // phase 3 — promotion: shard 0's follower takes over its lineage
    let promote = req(follower_addrs[0], "PROMOTE")?;
    if promote != "OK version=1 epoch=1" {
        return Err(Error::Invalid(format!("PROMOTE: {promote}")));
    }
    println!("  promoted shard-0 follower ({promote})");

    // LEARN service is restored: broadcast folds through the router,
    // replies byte-identical to the unsharded reference's
    for step in 0..learns {
        let line = format!("LEARN {} {}:1.0", step as usize % l, step as usize % n);
        let sharded = req(router.addr, &line)?;
        let unsharded = req(reference, &line)?;
        if sharded != unsharded {
            return Err(Error::Invalid(format!(
                "post-promotion LEARN {step} diverged:\n  sharded:   {sharded}\n  unsharded: {unsharded}"
            )));
        }
        if !sharded.starts_with(&format!("OK version={} ", 2 + step)) {
            return Err(Error::Invalid(format!("LEARN {step}: {sharded}")));
        }
    }
    let v_final = 1 + learns;

    // skew over the reachable fleet returns to 0 at the new version
    let stats = req(router.addr, "STATS")?;
    if !stats.contains(" skew=0") || !stats.contains(&format!("shards={shards}")) {
        return Err(Error::Invalid(format!("fleet should be converged at v{v_final}: {stats}")));
    }

    // scoring still byte-identical after the failover + folds
    for probe in &probes {
        let w = req(reference, probe)?;
        let got = req(router.addr, probe)?;
        if got != w {
            return Err(Error::Invalid(format!("post-promotion divergence on `{probe}`")));
        }
    }
    let errors = router.stats.errors.load(std::sync::atomic::Ordering::Relaxed);
    if errors != 0 {
        return Err(Error::Invalid(format!("router reported {errors} errors")));
    }
    router.shutdown();
    println!(
        "failover-check OK: one member killed per group served {total} requests with zero \
         drops, promotion restored LEARN (v1 -> v{v_final}), skew 0 over the surviving fleet"
    );
    Ok(())
}

/// Headless live-resharding check — the elastic N→M acceptance property,
/// across real OS processes:
///
/// 1. the trained model is split N ways and served by N shard processes
///    with the scatter-gather router in front, plus an unsharded
///    reference process for bitwise comparison;
/// 2. **under concurrent SCORE load**, the fleet is regrown to M = N+1:
///    a serve-side `RESHARD M` publishes an atomic M-way shard-set
///    version, M fresh processes come up on the new slices, and one
///    router `RESHARD` verb flips the fan-out map — every routed reply
///    before, during, and after the flip must be byte-identical to the
///    reference's, with zero drops;
/// 3. the old fleet is retired only after the flip (kill + `RELOAD`
///    re-slice both demonstrated), and the probes stay bitwise;
/// 4. both journals carry the reshard: `via=publish` on the serving
///    node, `via=flip` on the router.
fn cmd_reshard_check(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{multiline_request, text_request, Router, RouterConfig};
    use crate::error::Error;
    use crate::model::{split_artifact, ModelStore};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::{Duration, Instant};

    let dir = model_dir_arg(args, &args.str_or("dataset", "bibtex"));
    let old_shards: usize = args.parse_or("shards", 3usize);
    let new_shards = old_shards + 1;
    let load_threads: usize = args.parse_or("clients", 4usize);
    let per_thread: usize = args.parse_or("requests", 40usize);
    let source = ModelStore::open(&dir)?;
    let Some((src_version, artifact)) = source.load_latest()? else {
        return Err(Error::Invalid(format!(
            "no model versions in {} — run `fastpi train` first",
            dir.display()
        )));
    };
    drop(source);
    let (_, n, l) = artifact.shape();

    // scratch stores: unsharded reference plus one shard store the whole
    // fleet shares (the serve-side RESHARD publishes v2 into it)
    let base = std::env::temp_dir().join(format!("fastpi_reshard_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let ref_dir = base.join("ref");
    let shard_dir = base.join("shards");
    let mut fleet = Fleet::new()?;
    fleet.scratch.push(base.clone());
    assert_eq!(ModelStore::open(&ref_dir)?.publish(&artifact)?, 1);
    let set = split_artifact(&artifact, old_shards)?;
    assert_eq!(ModelStore::open(&shard_dir)?.publish_shard_set(&set)?, 1);
    println!(
        "split v{src_version} ({l} labels, rank {}) into {old_shards} shards under {}",
        artifact.rank(),
        base.display()
    );

    // spawn order (== Fleet child indices): reference, then the old fleet
    let reference = fleet.spawn_server(&[
        "serve".into(),
        "--model-dir".into(),
        ref_dir.display().to_string(),
        "--learn-batch".into(),
        "1".into(),
    ])?;
    println!("reference (unsharded) on {reference}");
    let mut old_addrs = Vec::new();
    for k in 0..old_shards {
        let addr = fleet.spawn_server(&[
            "serve".into(),
            "--model-dir".into(),
            shard_dir.display().to_string(),
            "--shard".into(),
            format!("{k}/{old_shards}"),
            "--learn-batch".into(),
            "1".into(),
        ])?;
        println!("shard {k}/{old_shards} on {addr}");
        old_addrs.push(addr);
    }
    let old_child = |k: usize| 1 + k;

    let groups: Vec<Vec<std::net::SocketAddr>> =
        old_addrs.iter().map(|&a| vec![a]).collect();
    let cfg = RouterConfig { upstream_timeout: Duration::from_secs(5), ..Default::default() };
    let router = Router::start_sharded(groups, cfg).map_err(Error::Io)?;

    let req = |addr, line: &str| text_request(addr, line).map_err(Error::Io);

    // expected replies pinned off the unsharded reference; `reassemble`
    // is bitwise, so they hold across the whole reshard
    let probes = [
        format!("SCORE 5 0:1.0,{}:0.5", n.saturating_sub(1)),
        "SCORE 1 0:1.0".to_string(),
        format!("SCORE {l} 1:0.25,2:-1.0"),
    ];
    let mut want = Vec::new();
    for probe in &probes {
        let w = req(reference, probe)?;
        if !w.starts_with("OK ") {
            return Err(Error::Invalid(format!("reference SCORE failed: {w}")));
        }
        want.push(w);
    }

    // concurrent load through the router; mid-load, grow the fleet to
    // M = N+1 and flip the fan-out map — not one reply may drop or differ
    let progress = AtomicUsize::new(0);
    let router_addr = router.addr;
    let total = load_threads * per_thread;
    let mut new_addrs: Vec<std::net::SocketAddr> = Vec::new();
    std::thread::scope(|s| -> crate::error::Result<()> {
        let mut handles = Vec::new();
        for t in 0..load_threads {
            let probes = &probes;
            let want = &want;
            let progress = &progress;
            handles.push(s.spawn(move || -> Result<usize, String> {
                let mut served = 0usize;
                for i in 0..per_thread {
                    let pi = (t + i) % probes.len();
                    let got = text_request(router_addr, &probes[pi])
                        .map_err(|e| format!("request io: {e}"))?;
                    if got != want[pi] {
                        return Err(format!(
                            "reply diverged across the reshard on `{}`:\n  got:  {got}\n  want: {}",
                            probes[pi], want[pi]
                        ));
                    }
                    served += 1;
                    progress.fetch_add(1, Ordering::Relaxed);
                }
                Ok(served)
            }));
        }
        // let the old fleet serve for a moment, then regrow it live
        let grow_after = total / 4;
        let deadline = Instant::now() + Duration::from_secs(120);
        while progress.load(Ordering::Relaxed) < grow_after {
            if Instant::now() > deadline {
                return Err(Error::Invalid("load never reached the reshard point".into()));
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        // (a) serve-side: publish the M-way shard-set version atomically
        let reply = text_request(old_addrs[0], &format!("RESHARD {new_shards}"))
            .map_err(Error::Io)?;
        if reply != format!("OK version=2 shards={new_shards}") {
            return Err(Error::Invalid(format!("serve-side RESHARD: {reply}")));
        }
        // (b) bring up the new fleet on the fresh slices
        for k in 0..new_shards {
            let addr = fleet.spawn_server(&[
                "serve".into(),
                "--model-dir".into(),
                shard_dir.display().to_string(),
                "--shard".into(),
                format!("{k}/{new_shards}"),
                "--learn-batch".into(),
                "1".into(),
            ])?;
            new_addrs.push(addr);
        }
        // (c) one verb flips the router onto it
        let spec: Vec<String> = new_addrs.iter().map(|a| a.to_string()).collect();
        let flip = text_request(router_addr, &format!("RESHARD {}", spec.join(",")))
            .map_err(Error::Io)?;
        if flip != format!("OK shards={new_shards}") {
            return Err(Error::Invalid(format!("router RESHARD: {flip}")));
        }
        println!(
            "  flipped {old_shards} -> {new_shards} shards after {} requests",
            progress.load(Ordering::Relaxed)
        );
        let mut served_total = 0usize;
        for h in handles {
            match h.join().expect("load thread panicked") {
                Ok(srv) => served_total += srv,
                Err(e) => return Err(Error::Invalid(e)),
            }
        }
        if served_total != total {
            return Err(Error::Invalid(format!(
                "dropped requests across the reshard: served {served_total} of {total}"
            )));
        }
        Ok(())
    })?;
    println!("  {total} routed SCOREs all byte-identical to the reference across the flip");

    // the new fleet is serving v2 slices, and the router agrees
    for (k, &addr) in new_addrs.iter().enumerate() {
        let v = req(addr, "VERSION")?;
        if !v.starts_with("VERSION id=2 ") || !v.ends_with(&format!("shard={k}/{new_shards}")) {
            return Err(Error::Invalid(format!("new shard {k}: {v}")));
        }
    }
    let stats = req(router.addr, "STATS")?;
    if !stats.contains(&format!(" shards={new_shards}")) || !stats.contains(" skew=0") {
        return Err(Error::Invalid(format!("router should see the new fleet: {stats}")));
    }

    // both journals carry the reshard
    let serve_events = multiline_request(old_addrs[0], "EVENTS").map_err(Error::Io)?;
    if !serve_events.contains(&format!("kind=reshard version=2 shards={new_shards} via=publish")) {
        return Err(Error::Invalid(format!("serve journal missing the publish: {serve_events}")));
    }
    let router_events = multiline_request(router.addr, "EVENTS").map_err(Error::Io)?;
    if !router_events
        .contains(&format!("kind=reshard shards={new_shards} members={new_shards} via=flip"))
    {
        return Err(Error::Invalid(format!("router journal missing the flip: {router_events}")));
    }

    // retire the old fleet: one member re-slices in place via RELOAD
    // (safe now — it is out of the fan-out map), the rest are killed
    let reload = req(old_addrs[1], &format!("RELOAD 1/{new_shards}"))?;
    if reload != format!("OK version=2 shard=1/{new_shards}") {
        return Err(Error::Invalid(format!("post-flip RELOAD re-slice: {reload}")));
    }
    for k in 0..old_shards {
        if k != 1 {
            fleet.kill(old_child(k));
        }
    }

    // scoring still byte-identical off the new fleet alone
    for (probe, w) in probes.iter().zip(&want) {
        let got = req(router.addr, probe)?;
        if got != *w {
            return Err(Error::Invalid(format!("post-retirement divergence on `{probe}`")));
        }
    }
    let errors = router.stats.errors.load(std::sync::atomic::Ordering::Relaxed);
    if errors != 0 {
        return Err(Error::Invalid(format!("router reported {errors} errors")));
    }
    router.shutdown();
    println!(
        "reshard-check OK: live {old_shards} -> {new_shards} reshard under {total} requests \
         with zero drops, old fleet retired after the flip"
    );
    Ok(())
}

fn cmd_datagen(args: &Args) -> crate::error::Result<()> {
    use crate::data::load_dataset;
    for name in datasets_arg(args) {
        let ds = load_dataset(
            &name,
            args.parse_or("scale", harness::DEFAULT_SCALE),
            args.parse_or("seed", 42),
            None,
        )?;
        let (m, n, l, nnz, spa, spy) = ds.stats();
        println!("{name}: m={m} n={n} L={l} |A|={nnz} sp(A)={spa:.4} sp(Y)={spy:.4}");
    }
    Ok(())
}

/// `fastpi analyze [--list] [--fix-list] [PATHS...]` — the in-tree
/// invariant linter (see `crate::analyze` for the lint catalogue).
fn cmd_analyze(args: &Args) -> crate::error::Result<()> {
    let positional = args.positional();
    let roots: Vec<std::path::PathBuf> = if positional.len() > 1 {
        positional[1..].iter().map(std::path::PathBuf::from).collect()
    } else {
        // default scan scope: everything that ships behavior
        ["rust/src", "rust/tests", "benches", "examples"]
            .iter()
            .map(std::path::PathBuf::from)
            .filter(|p| p.is_dir())
            .collect()
    };
    let report = crate::analyze::analyze_paths(&roots)?;
    let machine = args.flag("list") || args.flag("fix-list");
    for f in &report.findings {
        if machine {
            let mut line = format!("{}:{}:{} {} {}", f.file, f.line, f.col, f.lint, f.message);
            if args.flag("fix-list") {
                line.push_str(&format!(" [fix: {}]", f.fix));
            }
            println!("{line}");
        } else {
            println!("{}:{}:{} [{}] {}", f.file, f.line, f.col, f.lint, f.message);
            println!("    fix: {}", f.fix);
        }
    }
    if report.findings.is_empty() {
        println!(
            "analyze: clean — {} files scanned, {} suppressed finding(s)",
            report.files, report.suppressed
        );
        Ok(())
    } else {
        Err(crate::error::Error::Invalid(format!(
            "analyze: {} unsuppressed finding(s)",
            report.findings.len()
        )))
    }
}

fn cmd_selftest(args: &Args) -> crate::error::Result<()> {
    use crate::coordinator::{PinvJob, PipelineCoordinator};
    let coord = PipelineCoordinator::new();
    let scale = args.parse_or("scale", 0.05);
    for method in Method::PAPER_SET {
        let job = PinvJob { method, alpha: 0.3, k: 0.01, seed: 1 };
        let r = coord.run_on_dataset("bibtex", scale, &job)?;
        println!("{:<9} rank={} secs={:.3}", r.method, r.rank, r.svd_secs);
    }
    // artifact runtime smoke
    match crate::runtime::global_executor() {
        Some(_) => {
            let d = crate::runtime::GemmDispatcher::new(crate::runtime::ExecMode::ArtifactOnly);
            let mut rng = crate::util::rng::Rng::seed_from_u64(0);
            let a = crate::dense::Matrix::randn(100, 100, &mut rng);
            let b = crate::dense::Matrix::randn(100, 100, &mut rng);
            let c1 = d.matmul(&a, &b);
            let c2 = crate::dense::matmul(&a, &b);
            println!("artifact gemm max diff vs native: {:.2e}", c1.max_abs_diff(&c2));
        }
        None => println!("artifacts not built — runtime path skipped (run `make artifacts`)"),
    }
    println!("selftest OK");
    Ok(())
}
