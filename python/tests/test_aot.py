"""L2/AOT checks: entry points lower to valid HLO text, manifest naming is
stable, shapes line up."""

import jax
import pytest

from compile.aot import to_hlo_text
from compile.model import ENTRY_POINTS, entry_name, f32, matmul_entry


class TestEntryNaming:
    def test_matmul_name(self):
        assert entry_name("matmul", ((256, 128), (128, 64))) == "matmul_256x128x64"

    def test_powiter_name(self):
        assert entry_name("powiter", ((512, 256), (512, 64))) == "powiter_512x256x64"

    def test_score_name(self):
        assert entry_name("score", ((64, 512), (512, 256))) == "score_64x512x256"

    def test_unknown_kind_raises(self):
        with pytest.raises(ValueError):
            entry_name("nope", ((1, 1), (1, 1)))

    def test_all_entry_points_unique(self):
        names = [entry_name(k, s) for k, _, s in ENTRY_POINTS]
        assert len(names) == len(set(names))


class TestLowering:
    def test_matmul_lowers_to_hlo_text(self):
        lowered = jax.jit(matmul_entry).lower(f32(128, 128), f32(128, 128))
        text = to_hlo_text(lowered)
        assert "HloModule" in text
        # interpret-mode pallas must lower to plain HLO (no Mosaic custom-call)
        assert "mosaic" not in text.lower()

    def test_all_entries_lower(self):
        for kind, fn, shapes in ENTRY_POINTS:
            lowered = jax.jit(fn).lower(*[f32(*s) for s in shapes])
            text = to_hlo_text(lowered)
            assert "HloModule" in text, entry_name(kind, shapes)

    def test_entry_shapes_consistent(self):
        for kind, fn, shapes in ENTRY_POINTS:
            (s0, s1) = shapes
            if kind == "matmul":
                assert s0[1] == s1[0]
            elif kind == "powiter":
                assert s0[0] == s1[0]  # A: MxN, B: MxR
            elif kind == "score":
                assert s0[1] == s1[0]
