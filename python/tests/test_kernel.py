"""L1 correctness: Pallas kernel vs pure-jnp oracle (the CORE build-time
correctness signal), swept over shapes/tiles with hypothesis."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.matmul import matmul, vmem_bytes, _pick_tile
from compile.kernels.ref import matmul_ref, powiter_ref, score_ref

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")


def rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32)


class TestPickTile:
    def test_exact_divisor(self):
        assert _pick_tile(256, 128) == 128

    def test_falls_back_to_divisor(self):
        assert _pick_tile(100, 64) == 50

    def test_small_dim(self):
        assert _pick_tile(7, 128) == 7

    def test_prime(self):
        assert _pick_tile(13, 8) == 1


class TestMatmulKernel:
    @hypothesis.given(
        m=st.integers(1, 80),
        k=st.integers(1, 80),
        n=st.integers(1, 80),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_arbitrary_shapes(self, m, k, n, seed):
        x = rand((m, k), seed)
        y = rand((k, n), seed + 1)
        got = matmul(x, y, bm=32, bn=32, bk=32)
        want = matmul_ref(x, y)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @hypothesis.given(
        bm=st.sampled_from([8, 16, 32, 64, 128]),
        bn=st.sampled_from([8, 16, 32, 64, 128]),
        bk=st.sampled_from([8, 16, 32, 64, 128]),
    )
    def test_tile_sweep_on_fixed_shape(self, bm, bn, bk):
        x = rand((128, 128), 7)
        y = rand((128, 128), 8)
        got = matmul(x, y, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(got, matmul_ref(x, y), rtol=1e-5, atol=1e-5)

    def test_mxu_aligned_bucket(self):
        x = rand((256, 256), 1)
        y = rand((256, 256), 2)
        np.testing.assert_allclose(
            matmul(x, y), matmul_ref(x, y), rtol=1e-5, atol=1e-5
        )

    def test_rectangular_bucket(self):
        x = rand((1024, 256), 3)
        y = rand((256, 256), 4)
        np.testing.assert_allclose(
            matmul(x, y), matmul_ref(x, y), rtol=1e-5, atol=2e-5
        )

    def test_identity(self):
        x = rand((64, 64), 5)
        eye = jnp.eye(64, dtype=jnp.float32)
        np.testing.assert_allclose(matmul(x, eye), x, rtol=1e-6, atol=1e-6)

    def test_zeros(self):
        x = rand((32, 16), 6)
        z = jnp.zeros((16, 8), jnp.float32)
        assert float(jnp.abs(matmul(x, z)).max()) == 0.0

    def test_dtype_is_f32(self):
        out = matmul(rand((16, 16), 0), rand((16, 16), 1))
        assert out.dtype == jnp.float32


class TestComposedEntries:
    def test_powiter_matches_ref(self):
        from compile.model import powiter_entry

        a = rand((96, 48), 11)
        b = rand((96, 8), 12)
        (got,) = powiter_entry(a, b)
        np.testing.assert_allclose(got, powiter_ref(a, b), rtol=1e-4, atol=1e-4)

    def test_score_matches_ref(self):
        from compile.model import score_entry

        x = rand((16, 64), 13)
        z = rand((64, 32), 14)
        (got,) = score_entry(x, z)
        np.testing.assert_allclose(got, score_ref(x, z), rtol=1e-5, atol=1e-5)


class TestVmemBudget:
    def test_default_tile_fits_vmem(self):
        # 16 MiB VMEM budget on modern TPUs; default tile must fit with
        # comfortable double-buffering headroom.
        assert vmem_bytes() * 2 < 16 * 1024 * 1024

    def test_footprint_formula(self):
        assert vmem_bytes(128, 128, 128) == 4 * 3 * 128 * 128
