"""Layer 1 — Pallas tiled GEMM kernel.

The compute hot-spot of every SVD engine in FastPI is dense GEMM (randomized
projections, the incremental factor updates of Eq. 2/3, and the serving
scorer), so the L1 kernel is a block-tiled matmul.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks (M/bm, N/bn,
K/bk); each step streams one bm×bk panel of X and bk×bn panel of Y from HBM
into VMEM via BlockSpec index maps and feeds the MXU with a bm×bn f32
accumulation held in the revisited output block. Tile sizes default to
128×128×128 — MXU-aligned, 192 KiB of VMEM at f32, far under the ~16 MiB
budget, so the kernel is MXU-bound rather than memory-bound.

CPU execution uses interpret=True (the Mosaic TPU custom-call cannot run on
the CPU PJRT plugin); numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tile.
DEFAULT_TILE = 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One grid step: o[i,j] (+)= x[i,k] @ y[k,j].

    The output block is revisited along the K grid axis (its index_map
    ignores k), so it doubles as the VMEM accumulator: initialized on the
    first K step, accumulated in f32 on every step.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _pick_tile(dim: int, want: int) -> int:
    """Largest divisor of `dim` that is <= want (prefers `want` itself)."""
    t = min(want, dim)
    while dim % t != 0:
        t -= 1
    return max(t, 1)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(x, y, *, bm=DEFAULT_TILE, bn=DEFAULT_TILE, bk=DEFAULT_TILE, interpret=True):
    """C = X @ Y through the Pallas kernel.

    Shapes must tile evenly after `_pick_tile` clamping (all shapes do,
    since _pick_tile falls back to divisors). dtype: f32 in/out.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"matmul inner dim mismatch {x.shape} @ {y.shape}"
    bm = _pick_tile(m, bm)
    bn = _pick_tile(n, bn)
    bk = _pick_tile(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, y)


def vmem_bytes(bm=DEFAULT_TILE, bn=DEFAULT_TILE, bk=DEFAULT_TILE, dtype_bytes=4):
    """VMEM footprint of one grid step (analysis helper for DESIGN.md)."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)
