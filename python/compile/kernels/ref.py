"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
contract. pytest asserts kernel == ref across a hypothesis shape sweep."""

import jax.numpy as jnp


def matmul_ref(x, y):
    """C = X @ Y."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def powiter_ref(a, b):
    """One randomized-SVD subspace iteration: A @ (Aᵀ @ B)."""
    return matmul_ref(a, matmul_ref(a.T, b))


def score_ref(x, z):
    """Serving scorer: Ŷ = X @ Z."""
    return matmul_ref(x, z)
