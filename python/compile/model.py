"""Layer 2 — JAX entry points over the L1 Pallas kernel.

Each entry point is a fixed-shape jitted function that the AOT path
(`aot.py`) lowers once to HLO text. The rust runtime pads runtime operands
to the nearest bucket, executes the compiled artifact through PJRT, and
slices the result back. Python never runs after `make artifacts`.

Entry points:
  * matmul_MxKxN   — C = X·Y          (the GEMM hot path)
  * powiter_MxNxR  — B' = A·(Aᵀ·B)    (randomized-SVD subspace iteration)
  * score_BxNxL    — Ŷ = X·Z          (serving scorer, the request path)
"""

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul


def matmul_entry(x, y):
    """C = X @ Y via the Pallas kernel (1-tuple output for the AOT bridge)."""
    return (matmul(x, y),)


def powiter_entry(a, b):
    """One subspace iteration B' = A @ (Aᵀ @ B), both GEMMs through the L1
    kernel so they lower into a single fused HLO module."""
    z = matmul(jnp.transpose(a), b)
    return (matmul(a, z),)


def score_entry(x, z):
    """Batch scorer Ŷ = X @ Z for the serving path."""
    return (matmul(x, z),)


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# (kind, entry fn, operand shapes). Buckets cover the experiment sizes; the
# rust dispatcher zero-pads to the smallest bucket that fits.
ENTRY_POINTS = [
    ("matmul", matmul_entry, ((128, 128), (128, 128))),
    ("matmul", matmul_entry, ((256, 256), (256, 256))),
    ("matmul", matmul_entry, ((512, 512), (512, 512))),
    ("matmul", matmul_entry, ((1024, 256), (256, 256))),
    ("powiter", powiter_entry, ((512, 256), (512, 64))),
    ("score", score_entry, ((64, 512), (512, 256))),
    ("score", score_entry, ((64, 2048), (2048, 512))),
]


def entry_name(kind, shapes):
    """Stable artifact name, e.g. matmul_256x256x256 (M, K, N)."""
    (s0, s1) = shapes
    if kind == "matmul":
        m, k = s0
        _, n = s1
        return f"matmul_{m}x{k}x{n}"
    if kind == "powiter":
        m, n = s0
        _, r = s1
        return f"powiter_{m}x{n}x{r}"
    if kind == "score":
        b, n = s0
        _, l = s1
        return f"score_{b}x{n}x{l}"
    raise ValueError(kind)
