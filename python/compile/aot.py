"""AOT lowering: JAX entry points -> HLO *text* artifacts + manifest.

HLO text (NOT serialized HloModuleProto / jax .serialize()) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import pathlib

import jax
from jax._src.lib import xla_client as xc

from .model import ENTRY_POINTS, entry_name, f32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest_lines = []
    for kind, fn, shapes in ENTRY_POINTS:
        name = entry_name(kind, shapes)
        specs = [f32(*s) for s in shapes]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        (out_dir / fname).write_text(text)
        shape_str = ";".join("x".join(str(d) for d in s) for s in shapes)
        manifest_lines.append(f"{kind} {name} {fname} {shape_str}")
        print(f"  {name}: {len(text)} chars")

    (out_dir / "manifest.txt").write_text("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(manifest_lines)} artifacts + manifest to {out_dir}")


if __name__ == "__main__":
    main()
